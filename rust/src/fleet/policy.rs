//! Keep-alive policies (`KeepAlivePolicy`) — the fleet simulator's pluggable
//! answer to "how long does an idle instance stay warm?".
//!
//! The paper models the policy every major provider shipped in 2020: a fixed
//! idle-expiration threshold (AWS Lambda ~10 min). [`FixedExpiration`]
//! reproduces that model exactly — a 1-function fleet under it is
//! bit-identical to [`crate::sim::ServerlessSimulator`] (regression-tested).
//! Beyond the paper, [`HybridHistogramPolicy`] is a deterministic variant of
//! the histogram half of Azure's hybrid policy (Shahrad et al. 2020,
//! "Serverless in the Wild"): it learns each function's inter-arrival
//! distribution online and keeps instances warm just past the distribution's
//! tail, shrinking idle waste on predictable workloads without raising the
//! cold-start rate. [`StochasticExpiration`] mirrors the core simulator's
//! stochastic-threshold escape hatch ([`crate::sim::SimConfig`]'s
//! `expiration_process`).
//!
//! Policies are **per-function**: each simulated function gets its own
//! instance built from a [`PolicySpec`], so adaptive state never leaks
//! between functions and the sharded fleet runner stays deterministic for
//! any thread count.

use crate::sim::process::Process;
use crate::sim::rng::Rng;
use std::sync::Arc;

/// Decides the keep-alive window of idle instances for one function.
///
/// `keep_alive` is consulted every time an instance goes idle (one draw of
/// the expiration threshold); `on_arrival` lets adaptive policies observe
/// the function's arrival pattern. Implementations must be deterministic
/// given the same call sequence and `rng` state — the fleet determinism
/// contract (bit-identical results for any shard count) depends on it.
pub trait KeepAlivePolicy: Send {
    /// Keep-alive window in seconds for an instance going idle at `now`.
    fn keep_alive(&mut self, now: f64, rng: &mut Rng) -> f64;

    /// Observe a request arrival epoch at `now` (adaptive policies learn
    /// from the inter-arrival sequence; the default ignores it).
    fn on_arrival(&mut self, _now: f64) {}

    /// Opt the policy into prewarm (provisioning-lead) mode. Called once
    /// at engine construction when the fleet runs with a positive
    /// provisioning lead; policies without a prewarm arm ignore it (the
    /// default), in which case the fleet behaves bit-identically to a
    /// prewarm-disabled run.
    fn enable_prewarm(&mut self, _lead: f64) {}

    /// Predicted absolute time a warm instance should be ready (the
    /// head-percentile prewarm arm). Consulted by the engine when the idle
    /// pool drains; `None` (the default) schedules no prewarm.
    fn predict_next_arrival(&mut self, _now: f64) -> Option<f64> {
        None
    }

    /// Keep-alive window for a freshly prewarmed instance (covering the
    /// predicted arrival). Defaults to the ordinary window.
    fn prewarm_keep_alive(&mut self, now: f64, rng: &mut Rng) -> f64 {
        self.keep_alive(now, rng)
    }

    /// Human-readable description (used in policy-comparison reports).
    fn describe(&self) -> String;
}

/// The paper's provider model: a fixed idle-expiration threshold.
#[derive(Debug, Clone)]
pub struct FixedExpiration {
    pub threshold: f64,
}

impl FixedExpiration {
    pub fn new(threshold: f64) -> Self {
        assert!(threshold >= 0.0, "threshold must be non-negative");
        FixedExpiration { threshold }
    }
}

impl KeepAlivePolicy for FixedExpiration {
    fn keep_alive(&mut self, _now: f64, _rng: &mut Rng) -> f64 {
        self.threshold
    }

    fn describe(&self) -> String {
        format!("fixed({:.0}s)", self.threshold)
    }
}

/// Stochastic keep-alive window: one draw of `process` per idle period
/// (the fleet-level counterpart of `SimConfig::expiration_process`).
#[derive(Clone)]
pub struct StochasticExpiration {
    pub process: Process,
}

impl StochasticExpiration {
    pub fn new(process: Process) -> Self {
        StochasticExpiration { process }
    }
}

impl KeepAlivePolicy for StochasticExpiration {
    fn keep_alive(&mut self, _now: f64, rng: &mut Rng) -> f64 {
        // Raw sample, no clamping: `ServerlessSimulator::sample_expiration`
        // does not clamp either, and the bit-identity contract requires the
        // two paths to diverge nowhere. SimProcess is documented to produce
        // non-negative durations.
        self.process.sample(rng)
    }

    fn describe(&self) -> String {
        format!("stochastic({})", self.process.describe())
    }
}

/// Deterministic histogram half of Azure's hybrid keep-alive policy
/// (Shahrad et al. 2020): bin the function's observed inter-arrival times,
/// then keep idle instances warm for the tail percentile of that histogram
/// (plus a safety margin), capped at `range`.
///
/// Falls back to the conservative `range` window while the histogram is
/// still cold (fewer than `min_samples` observations) or when the pattern
/// escapes the histogram's range too often (`oob_threshold`) — the regimes
/// where the production policy defers to a default window or ARIMA
/// forecasting. The ARIMA arm remains out of scope.
///
/// **Prewarm (head-percentile) arm.** When the fleet runs with a positive
/// provisioning lead ([`KeepAlivePolicy::enable_prewarm`]), a confident
/// histogram switches to the production policy's split window: instances
/// unload immediately after serving (`keep_alive` returns 0) and the
/// engine provisions a fresh instance so it is warm from the
/// head-percentile predicted arrival
/// ([`KeepAlivePolicy::predict_next_arrival`] =
/// `last_arrival + head_bin·bin_len·(1 − margin)`) until the tail window
/// ([`KeepAlivePolicy::prewarm_keep_alive`]). Gaps below the head
/// percentile (≤ [`Self::DEFAULT_HEAD`] of traffic) pay a cold start —
/// the trade the production policy accepts for reclaiming the idle tail.
/// When the prediction cannot cover a future arrival (head percentile
/// inside bin 0 on high-rate functions, or the head edge already elapsed
/// by unload time), the policy keeps the ordinary tail window instead of
/// unloading into uncoverable cold starts. Everything stays
/// deterministic: no RNG draws in any arm.
#[derive(Debug, Clone)]
pub struct HybridHistogramPolicy {
    range: f64,
    bin_len: f64,
    tail: f64,
    margin: f64,
    min_samples: u64,
    oob_threshold: f64,
    prewarm: bool,
    bins: Vec<u64>,
    total: u64,
    oob: u64,
    last_arrival: Option<f64>,
}

impl HybridHistogramPolicy {
    /// Default tuning `(tail, margin, min_samples, oob_threshold)` — the
    /// single source for [`Self::new`], [`PolicySpec::hybrid_histogram`]
    /// and the scenario layer's `KeepAliveSpec::hybrid_histogram`.
    pub const DEFAULT_TUNING: (f64, f64, u64, f64) = (0.99, 0.10, 8, 0.5);

    /// Head percentile of the prewarm arm (Azure's hybrid policy uses the
    /// 5th percentile of the inter-arrival histogram as the pre-warming
    /// window).
    pub const DEFAULT_HEAD: f64 = 0.05;

    /// `range` is both the histogram span and the fallback keep-alive
    /// window; `bin_len` the bin width (Azure uses 1-minute bins over a
    /// 4-hour range). Tail percentile 0.99, margin 10%, 8 warm-up samples,
    /// 50% out-of-bounds fallback threshold.
    pub fn new(range: f64, bin_len: f64) -> Self {
        let (tail, margin, min_samples, oob_threshold) = Self::DEFAULT_TUNING;
        Self::with_params(range, bin_len, tail, margin, min_samples, oob_threshold)
    }

    pub fn with_params(
        range: f64,
        bin_len: f64,
        tail: f64,
        margin: f64,
        min_samples: u64,
        oob_threshold: f64,
    ) -> Self {
        assert!(range > 0.0 && bin_len > 0.0 && bin_len <= range);
        assert!((0.0..=1.0).contains(&tail));
        let n_bins = (range / bin_len).ceil() as usize;
        HybridHistogramPolicy {
            range,
            bin_len,
            tail,
            margin,
            min_samples,
            oob_threshold,
            prewarm: false,
            bins: vec![0; n_bins.max(1)],
            total: 0,
            oob: 0,
            last_arrival: None,
        }
    }

    /// Index of the bin at the configured tail percentile.
    fn tail_bin(&self) -> usize {
        let target = (self.total as f64 * self.tail).ceil() as u64;
        let mut prefix = 0u64;
        for (i, c) in self.bins.iter().enumerate() {
            prefix += c;
            if prefix >= target {
                return i;
            }
        }
        self.bins.len() - 1
    }

    /// Index of the bin at the head percentile (the prewarm arm).
    fn head_bin(&self) -> usize {
        let target = ((self.total as f64 * Self::DEFAULT_HEAD).ceil() as u64).max(1);
        let mut prefix = 0u64;
        for (i, c) in self.bins.iter().enumerate() {
            prefix += c;
            if prefix >= target {
                return i;
            }
        }
        self.bins.len() - 1
    }

    /// Whether the histogram is warm and in-range enough to trust.
    fn confident(&self) -> bool {
        self.total >= self.min_samples && self.oob_rate() < self.oob_threshold
    }

    /// Lower edge of the head-percentile bin, shrunk by the safety margin
    /// (the prewarmed instance is ready slightly *before* the predicted
    /// arrival, mirroring the tail window's symmetric enlargement).
    fn head_edge(&self) -> f64 {
        self.head_bin() as f64 * self.bin_len * (1.0 - self.margin).max(0.0)
    }

    /// True when the head-arm prediction can still cover an arrival
    /// strictly after `now` — the precondition for unloading an instance
    /// instead of keeping the tail window. False whenever the head
    /// percentile collapses into bin 0 (high-rate functions) or the
    /// predicted time already passed (service longer than the head edge):
    /// unloading there would guarantee a cold start the prewarm can never
    /// cover.
    fn prediction_usable(&self, now: f64) -> bool {
        match self.last_arrival {
            Some(last) => {
                let edge = self.head_edge();
                edge > 0.0 && last + edge > now
            }
            None => false,
        }
    }

    /// Fraction of observed inter-arrival times beyond the histogram range.
    pub fn oob_rate(&self) -> f64 {
        let seen = self.total + self.oob;
        if seen == 0 {
            0.0
        } else {
            self.oob as f64 / seen as f64
        }
    }

    /// Observations recorded so far (in-range).
    pub fn samples(&self) -> u64 {
        self.total
    }
}

impl KeepAlivePolicy for HybridHistogramPolicy {
    fn keep_alive(&mut self, now: f64, _rng: &mut Rng) -> f64 {
        if !self.confident() {
            // Cold histogram or pattern escapes the range: conservative
            // default window (the production policy's fallback arm).
            return self.range;
        }
        if self.prewarm && self.prediction_usable(now) {
            // Head-arm active: unload immediately after serving; the
            // engine's prewarm covers the predicted next arrival instead
            // of an idle keep-alive tail. Without a usable prediction
            // (gaps inside one bin, or the head edge already elapsed)
            // fall through to the tail window — unloading would turn
            // every subsequent request into an uncoverable cold start.
            return 0.0;
        }
        let window = (self.tail_bin() + 1) as f64 * self.bin_len * (1.0 + self.margin);
        window.min(self.range)
    }

    fn enable_prewarm(&mut self, lead: f64) {
        self.prewarm = lead > 0.0;
    }

    fn predict_next_arrival(&mut self, now: f64) -> Option<f64> {
        if !self.prewarm || !self.confident() || !self.prediction_usable(now) {
            return None;
        }
        Some(self.last_arrival? + self.head_edge())
    }

    fn prewarm_keep_alive(&mut self, now: f64, rng: &mut Rng) -> f64 {
        if !(self.prewarm && self.confident()) {
            return self.keep_alive(now, rng);
        }
        // Stay warm from the head-percentile ready time to the tail
        // window — the production policy's keep-alive half.
        let tail_window = (self.tail_bin() + 1) as f64 * self.bin_len * (1.0 + self.margin);
        (tail_window.min(self.range) - self.head_edge()).max(self.bin_len)
    }

    fn on_arrival(&mut self, now: f64) {
        if let Some(last) = self.last_arrival {
            let gap = (now - last).max(0.0);
            let bin = (gap / self.bin_len).floor() as usize;
            if bin < self.bins.len() {
                self.bins[bin] += 1;
                self.total += 1;
            } else {
                self.oob += 1;
            }
        }
        self.last_arrival = Some(now);
    }

    fn describe(&self) -> String {
        format!(
            "hybrid-histogram(range={:.0}s, bin={:.0}s, p{:.0}+{:.0}%)",
            self.range,
            self.bin_len,
            self.tail * 100.0,
            self.margin * 100.0
        )
    }
}

/// The two policy families selectable by name — the CLI's `--policy` flag
/// and the scenario reader's `policy.type` tag both parse through this, so
/// the accepted names and error text cannot drift apart. Parameters (fixed
/// threshold; histogram range/bin) ride separately.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PolicyKind {
    /// The paper's fixed idle-expiration threshold.
    Fixed,
    /// The Azure-style adaptive hybrid-histogram policy.
    Adaptive,
}

impl std::str::FromStr for PolicyKind {
    type Err = anyhow::Error;

    fn from_str(s: &str) -> Result<Self, Self::Err> {
        Ok(match s {
            "fixed" => PolicyKind::Fixed,
            "adaptive" | "hybrid" | "hybrid-histogram" => PolicyKind::Adaptive,
            other => anyhow::bail!("unknown policy {other:?} (expected fixed|adaptive)"),
        })
    }
}

/// Buildable policy description: the fleet configuration holds a spec, and
/// every function (in every shard) builds its own fresh policy instance
/// from it — the fleet analogue of `SimConfig::replica_with_seed`'s
/// fresh-process-state rule, and the reason adaptive policies do not break
/// the any-thread-count determinism contract.
#[derive(Clone)]
pub enum PolicySpec {
    /// The paper's fixed idle-expiration threshold.
    Fixed { threshold: f64 },
    /// Stochastic keep-alive window drawn from a process per idle period.
    Stochastic { process: Process },
    /// Deterministic histogram arm of Azure's hybrid policy.
    HybridHistogram {
        range: f64,
        bin_len: f64,
        tail: f64,
        margin: f64,
        min_samples: u64,
        oob_threshold: f64,
    },
    /// Any user-supplied policy, via a factory so each function gets an
    /// independent instance.
    Custom {
        label: String,
        build: Arc<dyn Fn() -> Box<dyn KeepAlivePolicy> + Send + Sync>,
    },
}

impl PolicySpec {
    pub fn fixed(threshold: f64) -> Self {
        PolicySpec::Fixed { threshold }
    }

    pub fn stochastic(process: Process) -> Self {
        PolicySpec::Stochastic { process }
    }

    /// Hybrid-histogram policy with the default tail/margin parameters.
    pub fn hybrid_histogram(range: f64, bin_len: f64) -> Self {
        let (tail, margin, min_samples, oob_threshold) = HybridHistogramPolicy::DEFAULT_TUNING;
        PolicySpec::HybridHistogram { range, bin_len, tail, margin, min_samples, oob_threshold }
    }

    pub fn custom<F>(label: impl Into<String>, build: F) -> Self
    where
        F: Fn() -> Box<dyn KeepAlivePolicy> + Send + Sync + 'static,
    {
        PolicySpec::Custom { label: label.into(), build: Arc::new(build) }
    }

    /// Build a fresh policy instance (one per function per run).
    pub fn build(&self) -> Box<dyn KeepAlivePolicy> {
        match self {
            PolicySpec::Fixed { threshold } => Box::new(FixedExpiration::new(*threshold)),
            PolicySpec::Stochastic { process } => {
                Box::new(StochasticExpiration::new(process.replica()))
            }
            PolicySpec::HybridHistogram {
                range,
                bin_len,
                tail,
                margin,
                min_samples,
                oob_threshold,
            } => Box::new(HybridHistogramPolicy::with_params(
                *range,
                *bin_len,
                *tail,
                *margin,
                *min_samples,
                *oob_threshold,
            )),
            PolicySpec::Custom { build, .. } => build(),
        }
    }

    pub fn describe(&self) -> String {
        match self {
            PolicySpec::Custom { label, .. } => label.clone(),
            other => other.build().describe(),
        }
    }
}

impl std::fmt::Debug for PolicySpec {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "PolicySpec({})", self.describe())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fixed_policy_is_constant_and_rng_free() {
        let mut p = FixedExpiration::new(600.0);
        let mut rng = Rng::new(1);
        let before = rng.clone().next_u64();
        for t in [0.0, 10.0, 1e6] {
            assert_eq!(p.keep_alive(t, &mut rng), 600.0);
        }
        // No RNG draws consumed — required for bit-identity with
        // ServerlessSimulator's constant-threshold path.
        assert_eq!(rng.next_u64(), before);
    }

    #[test]
    fn stochastic_policy_draws_from_process() {
        let mut p = StochasticExpiration::new(Process::exp_mean(100.0));
        let mut rng = Rng::new(2);
        let xs: Vec<f64> = (0..10_000).map(|i| p.keep_alive(i as f64, &mut rng)).collect();
        let mean = xs.iter().sum::<f64>() / xs.len() as f64;
        assert!((mean - 100.0).abs() < 5.0, "mean={mean}");
        assert!(xs.iter().all(|&x| x >= 0.0));
    }

    #[test]
    fn histogram_policy_defaults_to_range_while_cold() {
        let mut p = HybridHistogramPolicy::new(600.0, 10.0);
        let mut rng = Rng::new(3);
        assert_eq!(p.keep_alive(0.0, &mut rng), 600.0);
        // Below min_samples it still falls back.
        for k in 0..5 {
            p.on_arrival(k as f64 * 50.0);
        }
        assert_eq!(p.keep_alive(300.0, &mut rng), 600.0);
    }

    #[test]
    fn histogram_policy_learns_periodic_tail() {
        let mut p = HybridHistogramPolicy::new(600.0, 10.0);
        let mut rng = Rng::new(4);
        // Strictly periodic arrivals every 100 s.
        for k in 0..50 {
            p.on_arrival(k as f64 * 100.0);
        }
        // Tail bin = floor(100/10) = 10 -> window (10+1)*10*1.1 = 121 s:
        // just past the period, far below the 600 s fallback.
        let w = p.keep_alive(5_000.0, &mut rng);
        assert!((w - 121.0).abs() < 1e-9, "w={w}");
        assert_eq!(p.oob_rate(), 0.0);
        assert_eq!(p.samples(), 49);
    }

    #[test]
    fn histogram_policy_falls_back_when_out_of_range() {
        let mut p = HybridHistogramPolicy::new(600.0, 10.0);
        let mut rng = Rng::new(5);
        // Inter-arrival 5000 s >> range: every observation lands oob.
        for k in 0..20 {
            p.on_arrival(k as f64 * 5_000.0);
        }
        assert!(p.oob_rate() > 0.99);
        assert_eq!(p.keep_alive(1e5, &mut rng), 600.0);
    }

    #[test]
    fn spec_builds_fresh_instances() {
        let spec = PolicySpec::hybrid_histogram(600.0, 10.0);
        let mut a = spec.build();
        for k in 0..50 {
            a.on_arrival(k as f64 * 100.0);
        }
        let mut rng = Rng::new(6);
        let adapted = a.keep_alive(5_000.0, &mut rng);
        // A new build has no learned state.
        let fresh = spec.build().keep_alive(5_000.0, &mut rng);
        assert!(adapted < fresh, "adapted={adapted} fresh={fresh}");
        assert!(spec.describe().contains("hybrid-histogram"));
        assert!(PolicySpec::fixed(600.0).describe().contains("fixed"));
    }

    #[test]
    fn custom_spec_plugs_in() {
        let spec = PolicySpec::custom("always-5s", || Box::new(FixedExpiration::new(5.0)));
        let mut rng = Rng::new(7);
        assert_eq!(spec.build().keep_alive(0.0, &mut rng), 5.0);
        assert_eq!(spec.describe(), "always-5s");
    }

    #[test]
    fn hybrid_prewarm_arm_splits_head_and_tail() {
        let mut p = HybridHistogramPolicy::new(600.0, 10.0);
        p.enable_prewarm(15.0);
        let mut rng = Rng::new(8);
        // While the histogram is cold the fallback window still applies
        // and no prediction is made.
        assert_eq!(p.keep_alive(0.0, &mut rng), 600.0);
        assert_eq!(p.predict_next_arrival(0.0), None);
        // Strictly periodic arrivals every 100 s -> head bin == tail bin
        // == 10.
        for k in 0..50 {
            p.on_arrival(k as f64 * 100.0);
        }
        // Head arm: unload immediately...
        assert_eq!(p.keep_alive(4_901.0, &mut rng), 0.0);
        // ...be ready at last + 10*10*0.9 = 90 s after the last arrival...
        assert_eq!(p.predict_next_arrival(4_901.0), Some(4_900.0 + 90.0));
        // ...and stay warm from the head edge to the tail window:
        // 11*10*1.1 - 90 = 31 s.
        assert!((p.prewarm_keep_alive(4_990.0, &mut rng) - 31.0).abs() < 1e-9);
        // A prediction in the past yields nothing (no prewarm loops after
        // the workload goes quiet).
        assert_eq!(p.predict_next_arrival(5_200.0), None);
        // Disabling returns the tail keep-alive window.
        p.enable_prewarm(0.0);
        assert!((p.keep_alive(5_000.0, &mut rng) - 121.0).abs() < 1e-9);
        assert_eq!(p.predict_next_arrival(4_901.0), None);
    }

    #[test]
    fn hybrid_prewarm_falls_back_on_high_rate_workloads() {
        // Gaps shorter than one bin: the head percentile collapses into
        // bin 0, so no future arrival can ever be predicted. The prewarm
        // arm must keep the tail window instead of unloading into
        // guaranteed (uncoverable) cold starts.
        let mut p = HybridHistogramPolicy::new(600.0, 10.0);
        p.enable_prewarm(15.0);
        for k in 0..50 {
            p.on_arrival(k as f64 * 5.0);
        }
        let mut rng = Rng::new(10);
        assert_eq!(p.predict_next_arrival(246.0), None);
        // Tail bin is also bin 0 here: window = 1*10*1.1 = 11 s, not 0.
        let w = p.keep_alive(246.0, &mut rng);
        assert!((w - 11.0).abs() < 1e-9, "w={w}");
        // Same fallback when the service time outlives the head edge:
        // periodic 100 s arrivals (head edge 90) consulted 95 s after the
        // last arrival.
        let mut p = HybridHistogramPolicy::new(600.0, 10.0);
        p.enable_prewarm(15.0);
        for k in 0..50 {
            p.on_arrival(k as f64 * 100.0);
        }
        assert_eq!(p.predict_next_arrival(4_995.0), None);
        assert!((p.keep_alive(4_995.0, &mut rng) - 121.0).abs() < 1e-9);
    }

    #[test]
    fn non_adaptive_policies_ignore_prewarm() {
        let mut p = FixedExpiration::new(600.0);
        p.enable_prewarm(30.0);
        let mut rng = Rng::new(9);
        assert_eq!(p.predict_next_arrival(10.0), None);
        assert_eq!(p.keep_alive(10.0, &mut rng), 600.0);
        assert_eq!(p.prewarm_keep_alive(10.0, &mut rng), 600.0);
        let mut s = StochasticExpiration::new(Process::constant(5.0));
        s.enable_prewarm(30.0);
        assert_eq!(s.predict_next_arrival(10.0), None);
    }

    #[test]
    fn policy_kind_parses_names_and_aliases() {
        assert_eq!("fixed".parse::<PolicyKind>().unwrap(), PolicyKind::Fixed);
        for alias in ["adaptive", "hybrid", "hybrid-histogram"] {
            assert_eq!(alias.parse::<PolicyKind>().unwrap(), PolicyKind::Adaptive);
        }
        let err = "oracle".parse::<PolicyKind>().unwrap_err().to_string();
        assert!(err.contains("unknown policy"), "{err}");
        assert!(err.contains("fixed|adaptive"), "{err}");
    }
}
