//! What-if analysis (paper §4.3, Fig. 5): sweep the expiration threshold and
//! arrival rate, then use the optimizer to pick a cost/QoS-balanced
//! threshold for a given workload — the provider-side knob the paper's
//! conclusion highlights.
//!
//! Run with: `cargo run --release --example whatif_expiration`

use simfaas::figures;
use simfaas::output::{ascii_lines, Series, Table};
use simfaas::sim::SimConfig;
use simfaas::whatif::optimize_expiration_threshold;

fn main() {
    let rates = [0.1, 0.3, 0.5, 0.9, 1.5, 2.5];
    let thresholds = [120.0, 300.0, 600.0, 1200.0];
    println!("== Fig 5: P(cold) vs arrival rate for several thresholds ==\n");
    let out = figures::fig5_sweep(&rates, &thresholds, 200_000.0, 11);

    let mut table = Table::new(
        std::iter::once("rate".to_string())
            .chain(thresholds.iter().map(|t| format!("p%@{t}s")))
            .collect::<Vec<_>>(),
    );
    for (i, &rate) in rates.iter().enumerate() {
        let mut row = vec![rate];
        for (_, s) in &out {
            row.push(s[i].1 * 100.0);
        }
        table.row_f64(&row, 4);
    }
    print!("{table}\n");
    let series: Vec<Series> = out
        .iter()
        .map(|(th, s)| Series::new(format!("{th}s"), s.iter().map(|&(r, p)| (r, p * 100.0)).collect()))
        .collect();
    print!("{}", ascii_lines(&series, 64, 16));

    println!("\n== threshold optimization for the Table 1 workload ==");
    let base = SimConfig::table1().with_horizon(150_000.0);
    let grid = [60.0, 120.0, 300.0, 600.0, 1200.0, 2400.0];
    for (wc, wq, label) in [
        (1.0, 0.25, "cost-biased  (infra $ matters 4x more)"),
        (1.0, 1.0, "balanced"),
        (0.25, 1.0, "QoS-biased   (cold starts matter 4x more)"),
    ] {
        let (best, _) = optimize_expiration_threshold(&base, &grid, wc, wq);
        println!("  {label:<44} -> best threshold {best:>6.0} s");
    }
}
