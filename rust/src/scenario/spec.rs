//! The typed scenario description: every experiment the crate can run,
//! as one serializable value.
//!
//! A [`ScenarioSpec`] is the cartesian frame the CLI subcommands used to
//! wire by hand: **workload** (arrival process, batching) × **platform**
//! (service processes, expiration, concurrency limit) × **experiment**
//! (which engine: steady / temporal / ensemble / sweep / compare / fleet)
//! × **cost** (optional pricing pass) × **output** (table or JSON). The
//! spec is plain data — building one never runs anything; hand it to
//! [`crate::scenario::run_scenario`] to execute.
//!
//! Defaults everywhere mirror the paper's Table 1 configuration (and the
//! CLI's historical flag defaults), so `ScenarioSpec::new("x")` is exactly
//! the `simfaas steady` experiment.

use crate::cluster::ClusterConfig;
use crate::control::ControllerSpec;
use crate::cost::Provider;
use crate::fleet::PolicySpec;
use crate::figures::{COLD_MEAN, WARM_MEAN};
use crate::sim::fault::FaultProfile;
use crate::sim::process::{
    GammaProcess, LogNormalProcess, ParetoProcess, Process, WeibullProcess,
};
use crate::sim::retry::RetryPolicy;
use crate::sim::simulator::SimConfig;
use anyhow::{bail, Result};

/// Default RNG seed (the CLI's historical `--seed` default).
pub const DEFAULT_SEED: u64 = 0x5EED;

/// Serializable description of a stochastic process — the data half of
/// [`Process`]. `ExpRate`/`ExpMean` both build exponentials; keeping the
/// parameterization the user wrote makes specs round-trip losslessly.
#[derive(Debug, Clone, PartialEq)]
pub enum ProcessSpec {
    /// Exponential, parameterized by rate (events/s).
    ExpRate(f64),
    /// Exponential, parameterized by mean duration (s).
    ExpMean(f64),
    /// Deterministic fixed interval (s).
    Constant(f64),
    /// Gaussian truncated at zero.
    Gaussian { mean: f64, std: f64 },
    /// LogNormal by observed mean and coefficient of variation.
    LogNormal { mean: f64, cv: f64 },
    Gamma { shape: f64, scale: f64 },
    Weibull { shape: f64, scale: f64 },
    Pareto { x_m: f64, alpha: f64 },
    /// Bootstrap resampling over measured samples.
    Empirical(Vec<f64>),
    /// 2-state Markov-modulated Poisson process.
    Mmpp { rates: [f64; 2], switch: [f64; 2] },
}

impl ProcessSpec {
    /// Check parameters without building (the constructors `assert!`;
    /// scenario files must fail with an error, not a panic).
    pub fn validate(&self, what: &str) -> Result<()> {
        let ok = match self {
            ProcessSpec::ExpRate(r) => *r > 0.0,
            ProcessSpec::ExpMean(m) => *m > 0.0,
            ProcessSpec::Constant(v) => *v >= 0.0,
            ProcessSpec::Gaussian { std, .. } => *std >= 0.0,
            ProcessSpec::LogNormal { mean, cv } => *mean > 0.0 && *cv > 0.0,
            ProcessSpec::Gamma { shape, scale } | ProcessSpec::Weibull { shape, scale } => {
                *shape > 0.0 && *scale > 0.0
            }
            ProcessSpec::Pareto { x_m, alpha } => *x_m > 0.0 && *alpha > 0.0,
            ProcessSpec::Empirical(samples) => {
                !samples.is_empty() && samples.iter().all(|&x| x >= 0.0 && x.is_finite())
            }
            ProcessSpec::Mmpp { rates, switch } => {
                rates.iter().all(|&r| r > 0.0) && switch.iter().all(|&r| r > 0.0)
            }
        };
        if !ok {
            bail!("{what}: invalid parameters for {self:?}");
        }
        Ok(())
    }

    /// True when every draw is certainly 0 — degenerate processes that
    /// would freeze an arrival clock (the simulator reschedules the next
    /// arrival at `now + 0` forever). Checked for the arrival axis in
    /// [`ScenarioSpec::validate`].
    fn always_zero(&self) -> bool {
        match self {
            ProcessSpec::Constant(v) => *v == 0.0,
            ProcessSpec::Empirical(samples) => samples.iter().all(|&x| x == 0.0),
            // Truncation at zero makes a non-positive-mean, zero-std
            // Gaussian constant 0; with std > 0 positive draws remain
            // possible, so the clock still advances.
            ProcessSpec::Gaussian { mean, std } => *std == 0.0 && *mean <= 0.0,
            _ => false,
        }
    }

    /// Build the runnable [`Process`]. Call [`validate`](Self::validate)
    /// first when the parameters came from an untrusted file.
    pub fn build(&self) -> Process {
        match self {
            ProcessSpec::ExpRate(r) => Process::exp_rate(*r),
            ProcessSpec::ExpMean(m) => Process::exp_mean(*m),
            ProcessSpec::Constant(v) => Process::constant(*v),
            ProcessSpec::Gaussian { mean, std } => Process::gaussian(*mean, *std),
            ProcessSpec::LogNormal { mean, cv } => {
                LogNormalProcess::from_mean_cv(*mean, *cv).into()
            }
            ProcessSpec::Gamma { shape, scale } => GammaProcess::new(*shape, *scale).into(),
            ProcessSpec::Weibull { shape, scale } => WeibullProcess::new(*shape, *scale).into(),
            ProcessSpec::Pareto { x_m, alpha } => ParetoProcess::new(*x_m, *alpha).into(),
            ProcessSpec::Empirical(samples) => Process::empirical(samples.clone()),
            ProcessSpec::Mmpp { rates, switch } => Process::mmpp(*rates, *switch),
        }
    }
}

/// Serializable keep-alive policy for fleet experiments — the data half of
/// [`PolicySpec`] (which additionally offers non-serializable `Custom`
/// factories for programmatic use).
#[derive(Debug, Clone, PartialEq)]
pub enum KeepAliveSpec {
    /// The paper's fixed idle-expiration threshold.
    Fixed { threshold: f64 },
    /// One keep-alive draw from a process per idle period.
    Stochastic { process: ProcessSpec },
    /// Deterministic histogram arm of Azure's hybrid policy.
    HybridHistogram {
        range: f64,
        bin_len: f64,
        tail: f64,
        margin: f64,
        min_samples: u64,
        oob_threshold: f64,
    },
}

impl KeepAliveSpec {
    /// Default hybrid-histogram tuning `(tail, margin, min_samples,
    /// oob_threshold)`, shared with the fleet engine's builders so the
    /// CLI and scenario surfaces can never diverge.
    pub const HYBRID_DEFAULTS: (f64, f64, u64, f64) =
        crate::fleet::HybridHistogramPolicy::DEFAULT_TUNING;

    pub fn fixed(threshold: f64) -> Self {
        KeepAliveSpec::Fixed { threshold }
    }

    /// Hybrid-histogram policy with the default tail/margin tuning.
    pub fn hybrid_histogram(range: f64, bin_len: f64) -> Self {
        let (tail, margin, min_samples, oob_threshold) = Self::HYBRID_DEFAULTS;
        KeepAliveSpec::HybridHistogram { range, bin_len, tail, margin, min_samples, oob_threshold }
    }

    pub fn validate(&self) -> Result<()> {
        match self {
            KeepAliveSpec::Fixed { threshold } => {
                if *threshold < 0.0 {
                    bail!("policy: fixed threshold must be non-negative, got {threshold}");
                }
            }
            KeepAliveSpec::Stochastic { process } => process.validate("policy.process")?,
            KeepAliveSpec::HybridHistogram { range, bin_len, tail, margin, .. } => {
                if !(*range > 0.0 && *bin_len > 0.0 && *range >= *bin_len) {
                    bail!("policy: hybrid-histogram needs range >= bin_len > 0");
                }
                if !(0.0 < *tail && *tail <= 1.0) || *margin < 0.0 {
                    bail!("policy: hybrid-histogram needs 0 < tail <= 1 and margin >= 0");
                }
            }
        }
        Ok(())
    }

    /// Build the fleet-engine [`PolicySpec`].
    pub fn build(&self) -> PolicySpec {
        match self {
            KeepAliveSpec::Fixed { threshold } => PolicySpec::fixed(*threshold),
            KeepAliveSpec::Stochastic { process } => PolicySpec::stochastic(process.build()),
            KeepAliveSpec::HybridHistogram {
                range,
                bin_len,
                tail,
                margin,
                min_samples,
                oob_threshold,
            } => PolicySpec::HybridHistogram {
                range: *range,
                bin_len: *bin_len,
                tail: *tail,
                margin: *margin,
                min_samples: *min_samples,
                oob_threshold: *oob_threshold,
            },
        }
    }
}

/// The workload-source axis for fleet experiments: where the tenant mix
/// comes from. Single-function experiments always use the
/// [`WorkloadSpec::arrival`] process; fleet experiments default to the
/// synthetic mix and switch to a real ingested trace via
/// [`SourceSpec::AzureDataset`].
#[derive(Debug, Clone, PartialEq)]
pub enum SourceSpec {
    /// Synthetic Azure-style mix generated from the run seed (the
    /// default; `fleet.functions` sets the size).
    Synthetic,
    /// Real Azure Functions 2019 dataset read from a directory of the
    /// three published CSVs (see `workload::azure_dataset`). Transforms
    /// apply in order: `slice`, then `top_k`, then `scale_rate`.
    AzureDataset {
        /// Directory holding the three dataset CSVs. Relative paths in
        /// scenario files resolve against the file's own directory.
        dir: String,
        /// Keep only the K most-invoked functions.
        top_k: Option<usize>,
        /// Keep `[start, start+len)` of the function list (file order).
        slice: Option<(usize, usize)>,
        /// Multiply every function's rate profile (1.0 = as recorded).
        scale_rate: f64,
    },
}

/// The workload axis: what drives requests at the platform.
#[derive(Debug, Clone, PartialEq)]
pub struct WorkloadSpec {
    /// Inter-arrival time process.
    pub arrival: ProcessSpec,
    /// Optional batch-size process (each arrival epoch brings
    /// `max(1, round(sample))` simultaneous requests).
    pub batch_size: Option<ProcessSpec>,
    /// Optional trace source for fleet experiments (None = synthetic).
    pub source: Option<SourceSpec>,
}

impl Default for WorkloadSpec {
    fn default() -> Self {
        WorkloadSpec { arrival: ProcessSpec::ExpRate(0.9), batch_size: None, source: None }
    }
}

/// The platform axis: the paper's Table 1 input rows minus the workload.
#[derive(Debug, Clone, PartialEq)]
pub struct PlatformSpec {
    pub warm_service: ProcessSpec,
    pub cold_service: ProcessSpec,
    /// Idle expiration threshold in seconds.
    pub expiration_threshold: f64,
    /// Optional stochastic expiration threshold, overriding the constant.
    pub expiration_process: Option<ProcessSpec>,
    /// Maximum concurrency level (AWS Lambda default: 1000).
    pub max_concurrency: usize,
}

impl Default for PlatformSpec {
    fn default() -> Self {
        PlatformSpec {
            warm_service: ProcessSpec::ExpMean(WARM_MEAN),
            cold_service: ProcessSpec::ExpMean(COLD_MEAN),
            expiration_threshold: 600.0,
            expiration_process: None,
            max_concurrency: 1000,
        }
    }
}

/// The run axis: how long, what warm-up skip, which seed.
#[derive(Debug, Clone, PartialEq)]
pub struct RunSpec {
    /// Simulation horizon in seconds.
    pub horizon: f64,
    /// Warm-up window excluded from statistics (ignored by temporal runs,
    /// which measure from t = 0).
    pub skip_initial: f64,
    /// Root RNG seed; equal seeds give bit-identical scenarios.
    pub seed: u64,
}

impl Default for RunSpec {
    fn default() -> Self {
        RunSpec { horizon: 1e6, skip_initial: 100.0, seed: DEFAULT_SEED }
    }
}

/// Fleet experiment parameters (the `simfaas fleet` surface): a synthetic
/// Azure-style tenant mix derived from the run seed, under one keep-alive
/// policy, optionally compared against a fixed-threshold grid.
#[derive(Debug, Clone, PartialEq)]
pub struct FleetScenario {
    /// Number of functions in the synthetic mix.
    pub functions: usize,
    /// Worker threads for the sharded path; 0 = one per core.
    pub threads: usize,
    pub policy: KeepAliveSpec,
    /// Fleet-wide concurrent-instance cap (None = uncoupled).
    pub fleet_cap: Option<usize>,
    /// Memory allocated to every function (MB), for billing.
    pub memory_mb: f64,
    /// Report the top-K functions by request volume (table output).
    pub top_k: usize,
    /// Policy-comparison mode (entered when this grid **or**
    /// `compare_extra` is non-empty): every fixed threshold here plus
    /// every extra policy runs on the same mix.
    pub compare_thresholds: Vec<f64>,
    /// Extra policies appended to the comparison grid.
    pub compare_extra: Vec<KeepAliveSpec>,
    /// Provisioning lead time for prewarm events in seconds; 0 disables.
    /// With a positive lead the adaptive (hybrid-histogram) policy's
    /// head-percentile arm schedules instances *ahead* of predicted
    /// arrivals; fixed/stochastic policies predict nothing and run
    /// unchanged.
    pub prewarm_lead: f64,
    /// Finite-resource cluster replacing the flat capacity counter:
    /// hosts × memory × cpus × scheduler, with optional drain windows.
    /// Mutually exclusive with `fleet_cap`.
    pub cluster: Option<ClusterConfig>,
    /// Capacity domains for the capped/clustered paths: `> 1` shards
    /// the fleet into independent admission domains that run on scoped
    /// threads (function `i` → domain `i mod K`, proportional cap/host
    /// shares). `1` is the exact single-queue legacy path. Requires a
    /// `fleet_cap` or `cluster` when `> 1` (the uncapped path is
    /// already parallel).
    pub capacity_domains: usize,
    /// Autoscaling controller moving the fleet cap or the cluster host
    /// set on a fixed simulated-time tick ([`crate::control`]). Requires
    /// a `fleet_cap` or a `cluster` — there is nothing to actuate
    /// otherwise.
    pub controller: Option<ControllerSpec>,
}

impl FleetScenario {
    pub fn new(functions: usize) -> Self {
        FleetScenario {
            functions,
            threads: 0,
            policy: KeepAliveSpec::fixed(600.0),
            fleet_cap: None,
            memory_mb: 128.0,
            top_k: 5,
            compare_thresholds: Vec::new(),
            compare_extra: Vec::new(),
            prewarm_lead: 0.0,
            cluster: None,
            capacity_domains: 1,
            controller: None,
        }
    }

    pub fn with_policy(mut self, policy: KeepAliveSpec) -> Self {
        self.policy = policy;
        self
    }

    pub fn with_fleet_cap(mut self, cap: usize) -> Self {
        self.fleet_cap = Some(cap);
        self
    }

    pub fn with_threads(mut self, threads: usize) -> Self {
        self.threads = threads;
        self
    }

    pub fn with_comparison(
        mut self,
        thresholds: Vec<f64>,
        extra: Vec<KeepAliveSpec>,
    ) -> Self {
        self.compare_thresholds = thresholds;
        self.compare_extra = extra;
        self
    }

    /// Enable prewarm (provisioning-lead) events; 0 disables.
    pub fn with_prewarm_lead(mut self, lead: f64) -> Self {
        self.prewarm_lead = lead;
        self
    }

    /// Replace the flat capacity counter with a finite-resource cluster.
    pub fn with_cluster(mut self, cluster: ClusterConfig) -> Self {
        self.cluster = Some(cluster);
        self
    }

    /// Shard the capped/clustered paths into `k` capacity domains.
    pub fn with_capacity_domains(mut self, k: usize) -> Self {
        self.capacity_domains = k;
        self
    }

    /// Attach an autoscaling controller (see [`ControllerSpec`]).
    pub fn with_controller(mut self, spec: ControllerSpec) -> Self {
        self.controller = Some(spec);
        self
    }
}

/// The experiment axis: which engine consumes the other axes.
#[derive(Debug, Clone, PartialEq)]
pub enum ExperimentSpec {
    /// One steady-state run (paper Table 1).
    Steady,
    /// Transient analysis with replications and CI bands (Fig. 4).
    Temporal {
        replications: usize,
        /// Cumulative-average sampling interval; None = horizon/100
        /// (0.0 disables sampling entirely).
        sample_interval: Option<f64>,
        /// Initial warm pool of just-idle instances.
        warm_pool: usize,
    },
    /// Multi-threaded replication ensemble, mean ± 95% CI per metric;
    /// a non-empty `thresholds` grid sweeps expiration thresholds.
    Ensemble { replications: usize, threads: usize, thresholds: Vec<f64> },
    /// What-if sweep over rate × expiration threshold (Fig. 5).
    Sweep { rates: Vec<f64>, thresholds: Vec<f64> },
    /// Simulator vs the Markovian analytical baseline (both services
    /// collapse to exp(`service_mean`), which the models require).
    Compare { service_mean: f64, markovian_expiration: bool },
    /// Multi-function fleet under a keep-alive policy.
    Fleet(FleetScenario),
}

impl ExperimentSpec {
    pub fn temporal(replications: usize) -> Self {
        ExperimentSpec::Temporal { replications, sample_interval: None, warm_pool: 0 }
    }

    pub fn ensemble(replications: usize) -> Self {
        ExperimentSpec::Ensemble { replications, threads: 0, thresholds: Vec::new() }
    }

    /// Tag used in JSON and progress/report headers.
    pub fn kind(&self) -> &'static str {
        match self {
            ExperimentSpec::Steady => "steady",
            ExperimentSpec::Temporal { .. } => "temporal",
            ExperimentSpec::Ensemble { .. } => "ensemble",
            ExperimentSpec::Sweep { .. } => "sweep",
            ExperimentSpec::Compare { .. } => "compare",
            ExperimentSpec::Fleet(_) => "fleet",
        }
    }
}

/// The cost axis: price the primary run through a provider table
/// (paper §4.4). For fleet experiments only `provider` is consulted
/// (each function bills at its own `FleetScenario::memory_mb`).
#[derive(Debug, Clone, PartialEq)]
pub struct CostSpec {
    pub provider: Provider,
    /// Allocated memory (MB) for single-function billing.
    pub memory_mb: f64,
    /// Extra per-request charge from external services (USD).
    pub external_per_request: f64,
    /// Also report the estimate scaled to this window (s), e.g. 30 days.
    pub scale_to_window: Option<f64>,
}

impl Default for CostSpec {
    fn default() -> Self {
        CostSpec {
            provider: Provider::AwsLambda,
            memory_mb: 128.0,
            external_per_request: 0.0,
            scale_to_window: None,
        }
    }
}

impl CostSpec {
    /// The CLI `cost` subcommand's shape: provider + memory, scaled to a
    /// 30-day month.
    pub fn monthly(provider: Provider, memory_mb: f64) -> Self {
        CostSpec {
            provider,
            memory_mb,
            external_per_request: 0.0,
            scale_to_window: Some(30.0 * 86_400.0),
        }
    }
}

/// The reliability axis: fault injection plus the client retry policy
/// (see [`crate::sim::fault`] and [`crate::sim::retry`]). Consumed by the
/// steady and fleet experiments; the default is fully disabled and
/// bit-identical to a spec without the axis.
#[derive(Debug, Clone, PartialEq, Default)]
pub struct ReliabilitySpec {
    /// Failure probabilities, execution timeout, degradation windows.
    pub fault: FaultProfile,
    /// How clients re-submit failed / timed-out / rejected requests.
    pub retry: RetryPolicy,
}

impl ReliabilitySpec {
    pub fn new(fault: FaultProfile, retry: RetryPolicy) -> Self {
        ReliabilitySpec { fault, retry }
    }

    /// True when both halves are inert (the bit-identity default).
    pub fn is_disabled(&self) -> bool {
        self.fault.is_disabled() && self.retry.is_none()
    }

    pub fn validate(&self) -> Result<()> {
        self.fault.validate("reliability.fault")?;
        self.retry.validate("reliability.retry")
    }
}

/// The observability axis: telemetry capture and export (see
/// [`crate::telemetry`]). Consumed by the steady and fleet experiments;
/// capture draws no RNG and schedules no events, so attaching the axis
/// never changes simulation results.
#[derive(Debug, Clone, PartialEq, Default)]
pub struct ObservabilitySpec {
    /// Write per-request spans to this JSONL path; derived sibling files
    /// (`<stem>.perfetto.json`, `<stem>.metrics.csv`) carry the Chrome
    /// trace-event timeline and the internal-state time-series. `None`
    /// keeps recordings in memory (summary counts only).
    pub record_trace: Option<String>,
    /// Internal-state sampling interval in seconds; `<= 0` disables
    /// time-series sampling (spans are always recorded).
    pub metrics_interval: f64,
}

impl ObservabilitySpec {
    pub fn new(record_trace: Option<String>, metrics_interval: f64) -> Self {
        ObservabilitySpec { record_trace, metrics_interval }
    }

    pub fn validate(&self) -> Result<()> {
        if !(self.metrics_interval.is_finite() && self.metrics_interval >= 0.0) {
            bail!(
                "observability.metrics_interval must be a non-negative number of \
                 seconds (0 disables sampling), got {}",
                self.metrics_interval
            );
        }
        if let Some(path) = &self.record_trace {
            if path.is_empty() {
                bail!("observability.record_trace must be a non-empty file path");
            }
        }
        Ok(())
    }
}

/// How the report renders.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum OutputFormat {
    /// Human-readable tables/plots (the CLI's historical output).
    #[default]
    Table,
    /// One-line JSON on stdout.
    Json,
}

/// The output axis.
#[derive(Debug, Clone, PartialEq, Default)]
pub struct OutputSpec {
    pub format: OutputFormat,
}

/// One self-contained experiment description. See the module docs; build
/// fluently from [`ScenarioSpec::new`] or deserialize with
/// [`ScenarioSpec::from_json_str`].
#[derive(Debug, Clone, PartialEq)]
pub struct ScenarioSpec {
    /// Free-form label (reports and file headers).
    pub name: String,
    pub workload: WorkloadSpec,
    pub platform: PlatformSpec,
    pub run: RunSpec,
    pub experiment: ExperimentSpec,
    pub cost: Option<CostSpec>,
    /// Optional fault-injection + retry axis (steady and fleet runs).
    pub reliability: Option<ReliabilitySpec>,
    /// Optional telemetry capture/export axis (steady and fleet runs).
    pub observability: Option<ObservabilitySpec>,
    pub output: OutputSpec,
}

impl ScenarioSpec {
    /// The paper's Table 1 steady-state experiment, ready to customize.
    pub fn new(name: impl Into<String>) -> Self {
        ScenarioSpec {
            name: name.into(),
            workload: WorkloadSpec::default(),
            platform: PlatformSpec::default(),
            run: RunSpec::default(),
            experiment: ExperimentSpec::Steady,
            cost: None,
            reliability: None,
            observability: None,
            output: OutputSpec::default(),
        }
    }

    pub fn with_arrival(mut self, arrival: ProcessSpec) -> Self {
        self.workload.arrival = arrival;
        self
    }

    /// Poisson arrivals at `rate` req/s.
    pub fn with_arrival_rate(mut self, rate: f64) -> Self {
        self.workload.arrival = ProcessSpec::ExpRate(rate);
        self
    }

    pub fn with_batch_size(mut self, batch: ProcessSpec) -> Self {
        self.workload.batch_size = Some(batch);
        self
    }

    /// Select the workload source for a fleet experiment (e.g. a real
    /// Azure-trace directory).
    pub fn with_source(mut self, source: SourceSpec) -> Self {
        self.workload.source = Some(source);
        self
    }

    /// Resolve a relative `workload.source` dataset directory against
    /// `base` (typically the scenario file's parent directory), so bundled
    /// scenario files can reference the checked-in sample trace regardless
    /// of the working directory they are run from.
    pub fn resolve_source_paths(&mut self, base: &std::path::Path) {
        if let Some(SourceSpec::AzureDataset { dir, .. }) = &mut self.workload.source {
            let p = std::path::Path::new(dir.as_str());
            if p.is_relative() && !base.as_os_str().is_empty() {
                *dir = base.join(p).to_string_lossy().into_owned();
            }
        }
    }

    pub fn with_services(mut self, warm: ProcessSpec, cold: ProcessSpec) -> Self {
        self.platform.warm_service = warm;
        self.platform.cold_service = cold;
        self
    }

    pub fn with_expiration_threshold(mut self, secs: f64) -> Self {
        self.platform.expiration_threshold = secs;
        self
    }

    pub fn with_expiration_process(mut self, process: ProcessSpec) -> Self {
        self.platform.expiration_process = Some(process);
        self
    }

    pub fn with_max_concurrency(mut self, max: usize) -> Self {
        self.platform.max_concurrency = max;
        self
    }

    pub fn with_horizon(mut self, horizon: f64) -> Self {
        self.run.horizon = horizon;
        self
    }

    pub fn with_skip_initial(mut self, skip: f64) -> Self {
        self.run.skip_initial = skip;
        self
    }

    pub fn with_seed(mut self, seed: u64) -> Self {
        self.run.seed = seed;
        self
    }

    pub fn with_experiment(mut self, experiment: ExperimentSpec) -> Self {
        self.experiment = experiment;
        self
    }

    pub fn with_cost(mut self, cost: CostSpec) -> Self {
        self.cost = Some(cost);
        self
    }

    /// Attach the fault-injection + retry axis.
    pub fn with_reliability(mut self, reliability: ReliabilitySpec) -> Self {
        self.reliability = Some(reliability);
        self
    }

    /// Attach the telemetry capture/export axis.
    pub fn with_observability(mut self, observability: ObservabilitySpec) -> Self {
        self.observability = Some(observability);
        self
    }

    pub fn with_output(mut self, format: OutputFormat) -> Self {
        self.output.format = format;
        self
    }

    /// Lower the workload/platform/run axes into the core simulator input.
    /// Field-for-field the same construction the CLI subcommands used to
    /// do by hand — the scenario↔CLI bit-identity contract rests on it.
    pub fn sim_config(&self) -> SimConfig {
        SimConfig {
            arrival: self.workload.arrival.build(),
            batch_size: self.workload.batch_size.as_ref().map(ProcessSpec::build),
            warm_service: self.platform.warm_service.build(),
            cold_service: self.platform.cold_service.build(),
            expiration_threshold: self.platform.expiration_threshold,
            expiration_process: self.platform.expiration_process.as_ref().map(ProcessSpec::build),
            max_concurrency: self.platform.max_concurrency,
            horizon: self.run.horizon,
            skip_initial: self.run.skip_initial,
            seed: self.run.seed,
            capture_request_log: false,
            sample_interval: 0.0,
            fault: self
                .reliability
                .as_ref()
                .map(|r| r.fault.clone())
                .unwrap_or_default(),
            retry: self
                .reliability
                .as_ref()
                .map(|r| r.retry.clone())
                .unwrap_or_default(),
        }
    }

    /// Semantic validation — everything a well-formed JSON file can still
    /// get wrong. `run_scenario` calls this first, so spec errors surface
    /// as clean messages rather than engine panics.
    pub fn validate(&self) -> Result<()> {
        if !(self.run.horizon.is_finite() && self.run.horizon > 0.0) {
            bail!("run.horizon must be a positive number of seconds, got {}", self.run.horizon);
        }
        if !(self.run.skip_initial.is_finite() && self.run.skip_initial >= 0.0) {
            bail!("run.skip_initial must be non-negative, got {}", self.run.skip_initial);
        }
        self.workload.arrival.validate("workload.arrival")?;
        if self.workload.arrival.always_zero() {
            bail!(
                "workload.arrival: process always samples 0 s, which would stall \
                 simulated time instead of reaching the horizon"
            );
        }
        if let Some(b) = &self.workload.batch_size {
            b.validate("workload.batch_size")?;
        }
        if let Some(src) = &self.workload.source {
            // The source axis feeds the fleet engine only; silently
            // ignoring it elsewhere would defeat the typo protection.
            if !matches!(self.experiment, ExperimentSpec::Fleet(_)) {
                bail!(
                    "workload.source: the {} experiment does not take a trace \
                     source (the source axis applies to fleet)",
                    self.experiment.kind()
                );
            }
            if let SourceSpec::AzureDataset { dir, top_k, slice, scale_rate } = src {
                if dir.is_empty() {
                    bail!("workload.source.dir must be a non-empty directory path");
                }
                if *top_k == Some(0) {
                    bail!("workload.source.top_k must be at least 1 when set");
                }
                if let Some((_, len)) = slice {
                    if *len == 0 {
                        bail!("workload.source.slice length must be at least 1");
                    }
                }
                if !(scale_rate.is_finite() && *scale_rate > 0.0) {
                    bail!(
                        "workload.source.scale_rate must be a positive factor, got {scale_rate}"
                    );
                }
            }
        }
        self.platform.warm_service.validate("platform.warm_service")?;
        self.platform.cold_service.validate("platform.cold_service")?;
        if let Some(p) = &self.platform.expiration_process {
            p.validate("platform.expiration_process")?;
        }
        if self.platform.expiration_threshold < 0.0 {
            bail!("platform.expiration_threshold must be non-negative");
        }
        if self.platform.max_concurrency == 0 {
            bail!("platform.max_concurrency must be at least 1");
        }
        match &self.experiment {
            ExperimentSpec::Steady => {}
            ExperimentSpec::Temporal { replications, sample_interval, .. } => {
                if *replications == 0 {
                    bail!("temporal.replications must be at least 1");
                }
                if let Some(si) = sample_interval {
                    if !(si.is_finite() && *si >= 0.0) {
                        bail!("temporal.sample_interval must be non-negative seconds");
                    }
                }
            }
            ExperimentSpec::Ensemble { replications, thresholds, .. } => {
                if *replications == 0 {
                    bail!("ensemble.replications must be at least 1");
                }
                if thresholds.iter().any(|t| *t < 0.0 || !t.is_finite()) {
                    bail!("ensemble.thresholds must be non-negative seconds");
                }
            }
            ExperimentSpec::Sweep { rates, thresholds } => {
                if rates.is_empty() || thresholds.is_empty() {
                    bail!("sweep.rates and sweep.thresholds must be non-empty");
                }
                if rates.iter().any(|r| *r <= 0.0 || !r.is_finite()) {
                    bail!("sweep.rates must be positive req/s");
                }
                if thresholds.iter().any(|t| *t < 0.0 || !t.is_finite()) {
                    bail!("sweep.thresholds must be non-negative seconds");
                }
                // The grid itself drives these two axes: each point runs
                // Poisson(rate) arrivals at a constant threshold. Reject
                // spec combinations the sweep would silently ignore.
                if !matches!(
                    self.workload.arrival,
                    ProcessSpec::ExpRate(_) | ProcessSpec::ExpMean(_)
                ) {
                    bail!(
                        "sweep: the rate grid replaces workload.arrival with \
                         Poisson(rate) at every point, so a custom arrival process \
                         would be silently ignored — remove it"
                    );
                }
                if self.platform.expiration_process.is_some() {
                    bail!(
                        "sweep: platform.expiration_process would override every \
                         threshold in the grid — remove it (or use the ensemble \
                         experiment instead)"
                    );
                }
            }
            ExperimentSpec::Compare { service_mean, .. } => {
                if !(*service_mean > 0.0 && service_mean.is_finite()) {
                    bail!("compare.service_mean must be positive seconds");
                }
            }
            ExperimentSpec::Fleet(f) => {
                if f.functions == 0 {
                    bail!("fleet.functions must be at least 1");
                }
                if !(f.memory_mb.is_finite() && f.memory_mb > 0.0) {
                    bail!("fleet.memory_mb must be positive");
                }
                if f.fleet_cap == Some(0) {
                    bail!("fleet.fleet_cap must be at least 1 when set");
                }
                f.policy.validate()?;
                for p in &f.compare_extra {
                    p.validate()?;
                }
                if f.compare_thresholds.iter().any(|t| *t < 0.0 || !t.is_finite()) {
                    bail!("fleet.compare_thresholds must be non-negative seconds");
                }
                if !(f.prewarm_lead.is_finite() && f.prewarm_lead >= 0.0) {
                    bail!(
                        "fleet.prewarm_lead must be a non-negative number of seconds \
                         (0 disables prewarming), got {}",
                        f.prewarm_lead
                    );
                }
                if let Some(cl) = &f.cluster {
                    if f.fleet_cap.is_some() {
                        bail!(
                            "fleet.cluster and fleet.fleet_cap are mutually exclusive \
                             capacity models — a cluster's capacity is emergent from \
                             host bin-packing, a fleet_cap is a flat counter; remove \
                             one of the two fields"
                        );
                    }
                    if let Err(e) = cl.validate() {
                        bail!("fleet.cluster: {e}");
                    }
                }
                if let Some(ctl) = &f.controller {
                    if f.fleet_cap.is_none() && f.cluster.is_none() {
                        bail!(
                            "fleet.controller requires a fleet_cap or a cluster — \
                             an autoscaling controller has nothing to actuate on \
                             the uncapped path"
                        );
                    }
                    if let Err(e) = ctl.validate() {
                        bail!("fleet.controller: {e}");
                    }
                }
                if f.capacity_domains == 0 {
                    bail!("fleet.capacity_domains must be at least 1 (1 = no sharding)");
                }
                if f.capacity_domains > 1 {
                    if f.fleet_cap.is_none() && f.cluster.is_none() {
                        bail!(
                            "fleet.capacity_domains > 1 requires a fleet_cap or a \
                             cluster — the uncapped path is already parallel \
                             (set threads instead)"
                        );
                    }
                    if let Some(cap) = f.fleet_cap {
                        if f.capacity_domains > cap {
                            bail!(
                                "fleet.capacity_domains ({}) cannot exceed fleet_cap \
                                 ({cap}) — every domain needs at least one unit of \
                                 capacity",
                                f.capacity_domains
                            );
                        }
                    }
                    if let Some(cl) = &f.cluster {
                        if f.capacity_domains > cl.hosts {
                            bail!(
                                "fleet.capacity_domains ({}) cannot exceed \
                                 cluster.hosts ({}) — every domain needs at least \
                                 one host",
                                f.capacity_domains,
                                cl.hosts
                            );
                        }
                    }
                }
            }
        }
        if let Some(r) = &self.reliability {
            // The reliability axis feeds the steady and fleet engines;
            // silently ignoring it elsewhere would defeat the typo
            // protection the spec promises.
            if !matches!(
                self.experiment,
                ExperimentSpec::Steady | ExperimentSpec::Fleet(_)
            ) {
                bail!(
                    "reliability: the {} experiment does not inject faults \
                     (the reliability axis applies to steady and fleet)",
                    self.experiment.kind()
                );
            }
            r.validate()?;
        }
        if let Some(o) = &self.observability {
            // Telemetry capture is wired through the steady and fleet
            // engines; silently ignoring the axis elsewhere would defeat
            // the spec's typo protection.
            if !matches!(
                self.experiment,
                ExperimentSpec::Steady | ExperimentSpec::Fleet(_)
            ) {
                bail!(
                    "observability: the {} experiment does not record telemetry \
                     (the observability axis applies to steady and fleet)",
                    self.experiment.kind()
                );
            }
            o.validate()?;
        }
        if let Some(c) = &self.cost {
            // Only steady and fleet runs are priced; silently ignoring the
            // axis elsewhere would defeat the spec's typo protection.
            if !matches!(
                self.experiment,
                ExperimentSpec::Steady | ExperimentSpec::Fleet(_)
            ) {
                bail!(
                    "cost: the {} experiment does not price its results \
                     (the cost axis applies to steady and fleet)",
                    self.experiment.kind()
                );
            }
            if !(c.memory_mb.is_finite() && c.memory_mb > 0.0) {
                bail!("cost.memory_mb must be positive");
            }
            if c.external_per_request < 0.0 {
                bail!("cost.external_per_request must be non-negative");
            }
            if let Some(w) = c.scale_to_window {
                if !(w > 0.0 && w.is_finite()) {
                    bail!("cost.scale_to_window must be positive seconds");
                }
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_spec_is_table1() {
        let cfg = ScenarioSpec::new("t").sim_config();
        let t1 = SimConfig::table1();
        assert_eq!(cfg.expiration_threshold, t1.expiration_threshold);
        assert_eq!(cfg.max_concurrency, t1.max_concurrency);
        assert_eq!(cfg.horizon, t1.horizon);
        assert_eq!(cfg.skip_initial, t1.skip_initial);
        assert_eq!(cfg.seed, t1.seed);
        // The processes draw the same stream as Table 1's.
        let mut a = crate::sim::Rng::new(1);
        let mut b = crate::sim::Rng::new(1);
        for _ in 0..100 {
            assert_eq!(
                cfg.arrival.sample(&mut a).to_bits(),
                t1.arrival.sample(&mut b).to_bits()
            );
            assert_eq!(
                cfg.warm_service.sample(&mut a).to_bits(),
                t1.warm_service.sample(&mut b).to_bits()
            );
            assert_eq!(
                cfg.cold_service.sample(&mut a).to_bits(),
                t1.cold_service.sample(&mut b).to_bits()
            );
        }
    }

    #[test]
    fn process_specs_build_and_validate() {
        let specs = [
            ProcessSpec::ExpRate(0.9),
            ProcessSpec::ExpMean(2.0),
            ProcessSpec::Constant(1.0),
            ProcessSpec::Gaussian { mean: 1.0, std: 0.1 },
            ProcessSpec::LogNormal { mean: 2.0, cv: 0.5 },
            ProcessSpec::Gamma { shape: 2.0, scale: 1.0 },
            ProcessSpec::Weibull { shape: 2.0, scale: 1.0 },
            ProcessSpec::Pareto { x_m: 1.0, alpha: 2.0 },
            ProcessSpec::Empirical(vec![1.0, 2.0]),
            ProcessSpec::Mmpp { rates: [1.0, 5.0], switch: [0.1, 0.2] },
        ];
        let mut rng = crate::sim::Rng::new(3);
        for s in &specs {
            s.validate("x").unwrap();
            let x = s.build().sample(&mut rng);
            assert!(x >= 0.0 && x.is_finite());
        }
        assert!(ProcessSpec::ExpRate(0.0).validate("x").is_err());
        assert!(ProcessSpec::Empirical(vec![]).validate("x").is_err());
        assert!(ProcessSpec::Mmpp { rates: [1.0, 0.0], switch: [0.1, 0.1] }
            .validate("x")
            .is_err());
    }

    #[test]
    fn hybrid_defaults_match_policy_spec() {
        // KeepAliveSpec::hybrid_histogram must stay in lockstep with
        // PolicySpec::hybrid_histogram's default tuning.
        let a = KeepAliveSpec::hybrid_histogram(3_600.0, 60.0).build().describe();
        let b = PolicySpec::hybrid_histogram(3_600.0, 60.0).describe();
        assert_eq!(a, b);
    }

    #[test]
    fn validate_catches_semantic_errors() {
        let bad = ScenarioSpec::new("x").with_horizon(-5.0);
        assert!(bad.validate().unwrap_err().to_string().contains("horizon"));

        let bad = ScenarioSpec::new("x").with_experiment(ExperimentSpec::ensemble(0));
        assert!(bad.validate().unwrap_err().to_string().contains("replications"));

        let bad = ScenarioSpec::new("x")
            .with_experiment(ExperimentSpec::Fleet(FleetScenario::new(0)));
        assert!(bad.validate().unwrap_err().to_string().contains("functions"));

        let bad = ScenarioSpec::new("x").with_experiment(ExperimentSpec::Sweep {
            rates: vec![],
            thresholds: vec![600.0],
        });
        assert!(bad.validate().unwrap_err().to_string().contains("sweep"));

        let c = CostSpec { memory_mb: 0.0, ..CostSpec::default() };
        let bad = ScenarioSpec::new("x").with_cost(c);
        assert!(bad.validate().unwrap_err().to_string().contains("memory_mb"));

        let bad = ScenarioSpec::new("x").with_experiment(ExperimentSpec::Fleet(
            FleetScenario::new(2).with_prewarm_lead(-1.0),
        ));
        assert!(bad.validate().unwrap_err().to_string().contains("prewarm_lead"));
        ScenarioSpec::new("x")
            .with_experiment(ExperimentSpec::Fleet(
                FleetScenario::new(2).with_prewarm_lead(30.0),
            ))
            .validate()
            .unwrap();
    }

    #[test]
    fn validate_constrains_capacity_domains() {
        use crate::cluster::ClusterConfig;
        let fleet = |f: FleetScenario| {
            ScenarioSpec::new("x").with_experiment(ExperimentSpec::Fleet(f)).validate()
        };
        let err = |f| fleet(f).unwrap_err().to_string();
        // 0 is never valid; > 1 needs a capacity model to shard.
        let zero = FleetScenario::new(2).with_capacity_domains(0);
        assert!(err(zero).contains("at least 1"));
        let uncapped = FleetScenario::new(8).with_capacity_domains(2);
        assert!(err(uncapped).contains("fleet_cap"));
        // Each domain needs at least one unit of shared capacity.
        let thin_cap = FleetScenario::new(8).with_fleet_cap(2).with_capacity_domains(4);
        assert!(err(thin_cap).contains("cannot exceed fleet_cap"));
        let thin_cluster = FleetScenario::new(8)
            .with_cluster(ClusterConfig::new(2, 1024.0, 8.0))
            .with_capacity_domains(4);
        assert!(err(thin_cluster).contains("cluster.hosts"));
        // Well-formed capped and clustered shardings pass.
        let capped = FleetScenario::new(8).with_fleet_cap(16).with_capacity_domains(4);
        fleet(capped).unwrap();
        let clustered = FleetScenario::new(8)
            .with_cluster(ClusterConfig::new(4, 1024.0, 8.0))
            .with_capacity_domains(4);
        fleet(clustered).unwrap();
    }

    #[test]
    fn zero_interval_arrivals_are_rejected_not_hung() {
        // A process that always draws 0 would freeze simulated time
        // (arrivals reschedule at now+0 forever); validate must catch it.
        for arrival in [
            ProcessSpec::Constant(0.0),
            ProcessSpec::Empirical(vec![0.0, 0.0]),
            ProcessSpec::Gaussian { mean: -5.0, std: 0.0 },
        ] {
            let bad = ScenarioSpec::new("x").with_arrival(arrival.clone());
            let err = bad.validate().unwrap_err().to_string();
            assert!(err.contains("stall"), "{arrival:?}: {err}");
        }
        // Positive draws remain possible: these must stay valid.
        ScenarioSpec::new("x")
            .with_arrival(ProcessSpec::Empirical(vec![0.0, 1.0]))
            .validate()
            .unwrap();
        ScenarioSpec::new("x")
            .with_arrival(ProcessSpec::Gaussian { mean: -1.0, std: 2.0 })
            .validate()
            .unwrap();
        // And a zero *service* time is fine — only the arrival clock stalls.
        ScenarioSpec::new("x")
            .with_services(ProcessSpec::Constant(0.0), ProcessSpec::Constant(0.0))
            .validate()
            .unwrap();
    }

    #[test]
    fn sweep_rejects_axes_the_grid_would_silently_override() {
        let sweep = ExperimentSpec::Sweep { rates: vec![0.5], thresholds: vec![600.0] };
        // A custom arrival would be replaced by Poisson(rate) per point.
        let bad = ScenarioSpec::new("x")
            .with_arrival(ProcessSpec::Mmpp { rates: [1.0, 5.0], switch: [0.1, 0.1] })
            .with_experiment(sweep.clone());
        let err = bad.validate().unwrap_err().to_string();
        assert!(err.contains("arrival"), "{err}");
        // A stochastic expiration would defeat the whole threshold grid.
        let bad = ScenarioSpec::new("x")
            .with_expiration_process(ProcessSpec::ExpMean(600.0))
            .with_experiment(sweep.clone());
        let err = bad.validate().unwrap_err().to_string();
        assert!(err.contains("expiration_process"), "{err}");
        // Negative thresholds are as invalid as everywhere else.
        let bad = ScenarioSpec::new("x").with_experiment(ExperimentSpec::Sweep {
            rates: vec![0.5],
            thresholds: vec![-100.0],
        });
        assert!(bad.validate().unwrap_err().to_string().contains("thresholds"));
        // The CLI translator's shape stays valid.
        ScenarioSpec::new("x").with_experiment(sweep).validate().unwrap();
    }

    #[test]
    fn source_axis_restricted_to_fleet_and_validated() {
        let azure = |top_k, slice, scale_rate| SourceSpec::AzureDataset {
            dir: "traces/sample".into(),
            top_k,
            slice,
            scale_rate,
        };
        let fleet = ExperimentSpec::Fleet(FleetScenario::new(2));
        // Non-fleet experiments reject the axis instead of ignoring it.
        let bad = ScenarioSpec::new("x").with_source(SourceSpec::Synthetic);
        assert!(bad.validate().unwrap_err().to_string().contains("source"));
        // Fleet accepts both variants.
        ScenarioSpec::new("x")
            .with_experiment(fleet.clone())
            .with_source(SourceSpec::Synthetic)
            .validate()
            .unwrap();
        ScenarioSpec::new("x")
            .with_experiment(fleet.clone())
            .with_source(azure(Some(5), Some((0, 5)), 2.0))
            .validate()
            .unwrap();
        // Azure parameters are sanity-checked with the path named.
        for (src, needle) in [
            (azure(Some(0), None, 1.0), "top_k"),
            (azure(None, Some((3, 0)), 1.0), "slice"),
            (azure(None, None, 0.0), "scale_rate"),
            (
                SourceSpec::AzureDataset {
                    dir: String::new(),
                    top_k: None,
                    slice: None,
                    scale_rate: 1.0,
                },
                "dir",
            ),
        ] {
            let err = ScenarioSpec::new("x")
                .with_experiment(fleet.clone())
                .with_source(src)
                .validate()
                .unwrap_err()
                .to_string();
            assert!(err.contains(needle), "{err}");
        }
        // Relative dataset dirs resolve against a base; absolute stay put.
        let mut spec =
            ScenarioSpec::new("x").with_experiment(fleet.clone()).with_source(azure(None, None, 1.0));
        spec.resolve_source_paths(std::path::Path::new("/scenarios"));
        match &spec.workload.source {
            Some(SourceSpec::AzureDataset { dir, .. }) => {
                assert_eq!(dir, "/scenarios/traces/sample")
            }
            _ => unreachable!(),
        }
        let mut abs = ScenarioSpec::new("x").with_experiment(fleet).with_source(
            SourceSpec::AzureDataset {
                dir: "/data/azure".into(),
                top_k: None,
                slice: None,
                scale_rate: 1.0,
            },
        );
        abs.resolve_source_paths(std::path::Path::new("/elsewhere"));
        match &abs.workload.source {
            Some(SourceSpec::AzureDataset { dir, .. }) => assert_eq!(dir, "/data/azure"),
            _ => unreachable!(),
        }
    }

    #[test]
    fn reliability_axis_restricted_and_validated() {
        let armed = ReliabilitySpec::new(
            FaultProfile::disabled().with_failure_prob(0.05).with_timeout(30.0),
            RetryPolicy::exponential(0.1, 5.0, 3),
        );
        assert!(!armed.is_disabled());
        assert!(ReliabilitySpec::default().is_disabled());
        // Steady and fleet accept the axis...
        ScenarioSpec::new("x").with_reliability(armed.clone()).validate().unwrap();
        ScenarioSpec::new("x")
            .with_experiment(ExperimentSpec::Fleet(FleetScenario::new(2)))
            .with_reliability(armed.clone())
            .validate()
            .unwrap();
        // ...everything else rejects it instead of silently ignoring it.
        for experiment in [
            ExperimentSpec::temporal(2),
            ExperimentSpec::ensemble(2),
            ExperimentSpec::Sweep { rates: vec![0.5], thresholds: vec![600.0] },
            ExperimentSpec::Compare { service_mean: 2.0, markovian_expiration: false },
        ] {
            let bad = ScenarioSpec::new("x")
                .with_experiment(experiment.clone())
                .with_reliability(armed.clone());
            let err = bad.validate().unwrap_err().to_string();
            assert!(err.contains("reliability"), "{experiment:?}: {err}");
        }
        // Bad parameters surface with the axis path named: a timeout <= 0
        // and a zero-attempt retry are both spec errors, not panics.
        let bad = ScenarioSpec::new("x").with_reliability(ReliabilitySpec::new(
            FaultProfile::disabled().with_timeout(0.0),
            RetryPolicy::none(),
        ));
        let err = bad.validate().unwrap_err().to_string();
        assert!(err.contains("reliability.fault") && err.contains("timeout"), "{err}");
        let zero_attempts =
            RetryPolicy { max_attempts: 0, ..RetryPolicy::fixed(1.0, 3) };
        let bad = ScenarioSpec::new("x").with_reliability(ReliabilitySpec::new(
            FaultProfile::disabled(),
            zero_attempts,
        ));
        let err = bad.validate().unwrap_err().to_string();
        assert!(err.contains("reliability.retry"), "{err}");
    }

    #[test]
    fn observability_axis_restricted_and_validated() {
        let obs = ObservabilitySpec::new(Some("/tmp/spans.jsonl".into()), 60.0);
        // Steady and fleet accept the axis...
        ScenarioSpec::new("x").with_observability(obs.clone()).validate().unwrap();
        ScenarioSpec::new("x")
            .with_experiment(ExperimentSpec::Fleet(FleetScenario::new(2)))
            .with_observability(obs.clone())
            .validate()
            .unwrap();
        // ...everything else rejects it instead of silently ignoring it.
        for experiment in [
            ExperimentSpec::temporal(2),
            ExperimentSpec::ensemble(2),
            ExperimentSpec::Sweep { rates: vec![0.5], thresholds: vec![600.0] },
            ExperimentSpec::Compare { service_mean: 2.0, markovian_expiration: false },
        ] {
            let bad = ScenarioSpec::new("x")
                .with_experiment(experiment.clone())
                .with_observability(obs.clone());
            let err = bad.validate().unwrap_err().to_string();
            assert!(err.contains("observability"), "{experiment:?}: {err}");
        }
        // Bad parameters surface with the axis path named.
        let bad = ScenarioSpec::new("x")
            .with_observability(ObservabilitySpec::new(None, f64::NAN));
        let err = bad.validate().unwrap_err().to_string();
        assert!(err.contains("metrics_interval"), "{err}");
        let bad = ScenarioSpec::new("x")
            .with_observability(ObservabilitySpec::new(Some(String::new()), 0.0));
        let err = bad.validate().unwrap_err().to_string();
        assert!(err.contains("record_trace"), "{err}");
    }

    #[test]
    fn cost_axis_restricted_to_priced_experiments() {
        // Steady and fleet price their results; everything else must
        // reject the axis instead of silently ignoring it.
        ScenarioSpec::new("x").with_cost(CostSpec::default()).validate().unwrap();
        ScenarioSpec::new("x")
            .with_experiment(ExperimentSpec::Fleet(FleetScenario::new(2)))
            .with_cost(CostSpec::default())
            .validate()
            .unwrap();
        for experiment in [
            ExperimentSpec::temporal(2),
            ExperimentSpec::ensemble(2),
            ExperimentSpec::Sweep { rates: vec![0.5], thresholds: vec![600.0] },
            ExperimentSpec::Compare { service_mean: 2.0, markovian_expiration: false },
        ] {
            let bad = ScenarioSpec::new("x")
                .with_experiment(experiment.clone())
                .with_cost(CostSpec::default());
            let err = bad.validate().unwrap_err().to_string();
            assert!(err.contains("cost"), "{experiment:?}: {err}");
        }
    }
}
