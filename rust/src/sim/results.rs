//! Simulation results (the output half of the paper's Table 1).

/// Aggregated results of a steady-state or temporal simulation run.
///
/// Field names mirror the paper's Table 1 output rows; all times in seconds.
#[derive(Debug, Clone)]
pub struct SimResults {
    /// Simulated horizon actually measured (after skipping warm-up).
    pub measured_time: f64,
    /// Total requests that arrived in the measured window.
    pub total_requests: u64,
    /// Requests served by a fresh (cold-started) instance.
    pub cold_requests: u64,
    /// Requests served by a warm (idle) instance.
    pub warm_requests: u64,
    /// Requests rejected at the maximum concurrency level.
    pub rejected_requests: u64,
    /// P(cold start) among served requests — paper Table 1 "*Cold Start
    /// Probability".
    pub cold_start_prob: f64,
    /// P(rejection) among all arrivals — "*Rejection Probability".
    pub rejection_prob: f64,
    /// Mean lifespan of terminated instances — "*Average Instance Lifespan".
    pub avg_lifespan: f64,
    /// Number of instances that were created in the measured window.
    pub instances_created: u64,
    /// Number of instances that expired in the measured window.
    pub instances_expired: u64,
    /// Time-weighted mean of the total instance count — "*Average Server
    /// Count" (the provider's infrastructure footprint).
    pub avg_server_count: f64,
    /// Time-weighted mean of the busy instance count — "*Average Running
    /// Servers" (what the developer is billed for).
    pub avg_running_count: f64,
    /// Time-weighted mean of the idle instance count — "*Average Idle
    /// Count".
    pub avg_idle_count: f64,
    /// Peak total instance count observed.
    pub max_server_count: f64,
    /// avg_idle / avg_server — the paper's Fig. 8 "wasted capacity".
    pub wasted_capacity: f64,
    /// Mean response time over served requests.
    pub avg_response_time: f64,
    /// Mean response time over warm requests only (= mean warm service).
    pub avg_warm_response_time: f64,
    /// Mean response time over cold requests only.
    pub avg_cold_response_time: f64,
    /// Streaming P50 / P95 / P99 of response time.
    pub response_p50: f64,
    pub response_p95: f64,
    pub response_p99: f64,
    /// Total billed instance-seconds in the measured window (runtime
    /// charges are proportional to this).
    pub billed_instance_seconds: f64,
    /// Observed mean arrival rate (sanity check against the input process).
    pub observed_arrival_rate: f64,
    /// Portion of simulated time at each total-instance-count level
    /// (Fig. 3). `instance_count_pmf[k]` = fraction of time with k
    /// instances.
    pub instance_count_pmf: Vec<f64>,
    /// Instances started by the prewarm (provisioning-lead) path in the
    /// measured window. 0 unless the engine runs with a positive
    /// provisioning lead (see `sim::core`).
    pub prewarm_starts: u64,
    /// Total lifespan of prewarmed instances that expired without serving
    /// a single request — the prewarm arm's speculative waste.
    pub wasted_prewarm_seconds: f64,
    /// Dispatched requests that failed transiently (fault injection; they
    /// are a subset of cold+warm, ran their whole busy period and were
    /// billed, but returned an error).
    pub failed_requests: u64,
    /// Dispatched requests cut off at the fault profile's execution
    /// timeout (also a subset of cold+warm; billed up to the deadline).
    pub timeout_requests: u64,
    /// Admitted cold starts whose provisioning failed before any instance
    /// materialized (counted in `total_requests` but in none of
    /// cold/warm/rejected).
    pub coldstart_failures: u64,
    /// Retry re-arrivals in the measured window (already included in
    /// `total_requests` — the retry-amplified load).
    pub retry_attempts: u64,
    /// Failures that were final because max-attempts or the run-wide retry
    /// budget was exhausted.
    pub retry_exhausted: u64,
    /// Billed busy-seconds spent on executions that failed or timed out —
    /// work the developer paid for with no successful response.
    pub wasted_work_seconds: f64,
    /// Successful responses per second of measured time:
    /// `(cold + warm - failed - timeout) / measured_time`.
    pub goodput: f64,
}

impl SimResults {
    /// Utilized capacity ratio = running / total (1 - wasted).
    pub fn utilized_capacity(&self) -> f64 {
        if self.avg_server_count <= 0.0 {
            0.0
        } else {
            self.avg_running_count / self.avg_server_count
        }
    }

    /// Fraction of arrivals that got a successful response:
    /// `(cold + warm - failed - timeout) / total`. 1.0 when nothing
    /// arrived.
    pub fn success_rate(&self) -> f64 {
        if self.total_requests == 0 {
            return 1.0;
        }
        let ok = (self.cold_requests + self.warm_requests)
            .saturating_sub(self.failed_requests + self.timeout_requests);
        ok as f64 / self.total_requests as f64
    }

    /// Render the Table-1-style two-column report.
    pub fn to_table(&self) -> String {
        let rows = [
            ("*Cold Start Probability", format!("{:.4} %", self.cold_start_prob * 100.0)),
            ("*Rejection Probability", format!("{:.4} %", self.rejection_prob * 100.0)),
            ("*Average Instance Lifespan", format!("{:.4} s", self.avg_lifespan)),
            ("*Average Server Count", format!("{:.4}", self.avg_server_count)),
            ("*Average Running Servers", format!("{:.4}", self.avg_running_count)),
            ("*Average Idle Count", format!("{:.4}", self.avg_idle_count)),
            ("*Average Wasted Capacity", format!("{:.4} %", self.wasted_capacity * 100.0)),
            ("*Average Response Time", format!("{:.4} s", self.avg_response_time)),
            ("*Response Time P99", format!("{:.4} s", self.response_p99)),
            ("Requests (total/cold/warm/rej)", format!(
                "{}/{}/{}/{}",
                self.total_requests, self.cold_requests, self.warm_requests, self.rejected_requests
            )),
            ("*Success Rate", format!("{:.4} %", self.success_rate() * 100.0)),
            ("*Goodput", format!("{:.4} req/s", self.goodput)),
            ("Failures (transient/timeout/coldstart)", format!(
                "{}/{}/{}",
                self.failed_requests, self.timeout_requests, self.coldstart_failures
            )),
            ("Retries (attempts/exhausted)", format!(
                "{}/{}",
                self.retry_attempts, self.retry_exhausted
            )),
            ("Wasted Work", format!("{:.4} s", self.wasted_work_seconds)),
        ];
        let w = rows.iter().map(|(k, _)| k.len()).max().unwrap_or(0);
        let mut s = String::new();
        for (k, v) in rows {
            s.push_str(&format!("{k:<w$}  {v}\n"));
        }
        s
    }
}

impl std::fmt::Display for SimResults {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{}", self.to_table())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn dummy() -> SimResults {
        SimResults {
            measured_time: 1e6,
            total_requests: 900_000,
            cold_requests: 1260,
            warm_requests: 898_740,
            rejected_requests: 0,
            cold_start_prob: 0.0014,
            rejection_prob: 0.0,
            avg_lifespan: 6307.7,
            instances_created: 1260,
            instances_expired: 1255,
            avg_server_count: 7.6795,
            avg_running_count: 1.7902,
            avg_idle_count: 5.8893,
            max_server_count: 14.0,
            wasted_capacity: 5.8893 / 7.6795,
            avg_response_time: 1.9915,
            avg_warm_response_time: 1.991,
            avg_cold_response_time: 2.244,
            response_p50: 1.38,
            response_p95: 5.96,
            response_p99: 9.17,
            billed_instance_seconds: 1.79e6,
            observed_arrival_rate: 0.9,
            instance_count_pmf: vec![0.0, 0.1, 0.2, 0.3, 0.4],
            prewarm_starts: 0,
            wasted_prewarm_seconds: 0.0,
            failed_requests: 0,
            timeout_requests: 0,
            coldstart_failures: 0,
            retry_attempts: 0,
            retry_exhausted: 0,
            wasted_work_seconds: 0.0,
            goodput: 0.9,
        }
    }

    #[test]
    fn table_contains_paper_rows() {
        let t = dummy().to_table();
        assert!(t.contains("Cold Start Probability"));
        assert!(t.contains("Average Instance Lifespan"));
        assert!(t.contains("Average Server Count"));
        assert!(t.contains("0.1400 %"));
    }

    #[test]
    fn utilized_plus_wasted_is_one() {
        let r = dummy();
        assert!((r.utilized_capacity() + r.wasted_capacity - 1.0).abs() < 1e-9);
    }

    #[test]
    fn table_contains_reliability_rows() {
        let t = dummy().to_table();
        assert!(t.contains("Success Rate"));
        assert!(t.contains("Goodput"));
        assert!(t.contains("Failures (transient/timeout/coldstart)"));
        assert!(t.contains("Retries (attempts/exhausted)"));
        assert!(t.contains("Wasted Work"));
    }

    #[test]
    fn success_rate_counts_failures_against_served() {
        let mut r = dummy();
        assert!((r.success_rate() - (900_000.0 / 900_000.0)).abs() < 1e-12);
        r.failed_requests = 90_000;
        r.timeout_requests = 10_000;
        assert!((r.success_rate() - (800_000.0 / 900_000.0)).abs() < 1e-12);
    }

    #[test]
    fn pmf_preserved() {
        let r = dummy();
        assert_eq!(r.instance_count_pmf.len(), 5);
        assert!((r.instance_count_pmf.iter().sum::<f64>() - 1.0).abs() < 1e-12);
    }
}
