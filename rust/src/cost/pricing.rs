//! Provider pricing tables (2020-era public list prices, matching the
//! paper's timeframe; the engine takes any table, so updating prices is a
//! data change).

/// Serverless providers with built-in pricing tables.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Provider {
    AwsLambda,
    GoogleCloudFunctions,
    AzureFunctions,
    IbmCloudFunctions,
}

impl Provider {
    /// Canonical short name — the form the CLI accepts and the scenario
    /// writer emits (`FromStr` accepts these plus longer aliases).
    pub fn canonical_name(&self) -> &'static str {
        match self {
            Provider::AwsLambda => "aws",
            Provider::GoogleCloudFunctions => "gcf",
            Provider::AzureFunctions => "azure",
            Provider::IbmCloudFunctions => "ibm",
        }
    }
}

/// Shared string→provider parsing for the CLI (`--provider`) and the
/// scenario JSON reader (`cost.provider`), so the accepted names and the
/// error message cannot drift between the two surfaces.
impl std::str::FromStr for Provider {
    type Err = anyhow::Error;

    fn from_str(s: &str) -> Result<Self, Self::Err> {
        Ok(match s {
            "aws" | "aws-lambda" | "lambda" => Provider::AwsLambda,
            "gcf" | "google" | "google-cloud-functions" => Provider::GoogleCloudFunctions,
            "azure" | "azure-functions" => Provider::AzureFunctions,
            "ibm" | "ibm-cloud-functions" => Provider::IbmCloudFunctions,
            other => anyhow::bail!(
                "unknown provider {other:?} (expected aws|gcf|google|azure|ibm)"
            ),
        })
    }
}

/// Billing rates.
#[derive(Debug, Clone, Copy)]
pub struct PricingTable {
    pub provider: Provider,
    /// USD per request.
    pub per_request: f64,
    /// USD per GB-second of billed execution.
    pub per_gb_second: f64,
    /// Provider-side infrastructure cost per provisioned instance-hour per
    /// GB of memory (USD). Public clouds do not publish this; we use an
    /// EC2-like on-demand rate as the linear proxy the paper describes
    /// ("the average total server count is linearly proportional to the
    /// infrastructure cost incurred by the serverless provider").
    pub infra_cost_per_instance_hour: f64,
}

impl PricingTable {
    /// AWS Lambda, 2020: $0.20 per 1M requests, $0.0000166667 per GB-s.
    pub fn aws_lambda() -> Self {
        PricingTable {
            provider: Provider::AwsLambda,
            per_request: 0.20 / 1e6,
            per_gb_second: 0.000_016_666_7,
            infra_cost_per_instance_hour: 0.0116, // t3.micro-like per GB-h
        }
    }

    /// Google Cloud Functions, 2020: $0.40 per 1M requests and a combined
    /// CPU+memory rate ~ $0.0000165 per GB-s at 128 MB-class configs.
    pub fn google_cloud_functions() -> Self {
        PricingTable {
            provider: Provider::GoogleCloudFunctions,
            per_request: 0.40 / 1e6,
            per_gb_second: 0.000_016_5,
            infra_cost_per_instance_hour: 0.0118,
        }
    }

    /// Azure Functions consumption plan, 2020: $0.20 per 1M executions,
    /// $0.000016 per GB-s.
    pub fn azure_functions() -> Self {
        PricingTable {
            provider: Provider::AzureFunctions,
            per_request: 0.20 / 1e6,
            per_gb_second: 0.000_016,
            infra_cost_per_instance_hour: 0.0115,
        }
    }

    /// IBM Cloud Functions, 2020: $0.000017 per GB-s, no per-request fee.
    pub fn ibm_cloud_functions() -> Self {
        PricingTable {
            provider: Provider::IbmCloudFunctions,
            per_request: 0.0,
            per_gb_second: 0.000_017,
            infra_cost_per_instance_hour: 0.0117,
        }
    }

    pub fn for_provider(p: Provider) -> Self {
        match p {
            Provider::AwsLambda => Self::aws_lambda(),
            Provider::GoogleCloudFunctions => Self::google_cloud_functions(),
            Provider::AzureFunctions => Self::azure_functions(),
            Provider::IbmCloudFunctions => Self::ibm_cloud_functions(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tables_are_positive_and_distinct() {
        for p in [
            Provider::AwsLambda,
            Provider::GoogleCloudFunctions,
            Provider::AzureFunctions,
            Provider::IbmCloudFunctions,
        ] {
            let t = PricingTable::for_provider(p);
            assert_eq!(t.provider, p);
            assert!(t.per_gb_second > 0.0);
            assert!(t.infra_cost_per_instance_hour > 0.0);
        }
        assert_eq!(PricingTable::ibm_cloud_functions().per_request, 0.0);
    }

    #[test]
    fn aws_million_requests_costs_20_cents() {
        let t = PricingTable::aws_lambda();
        assert!((t.per_request * 1e6 - 0.20).abs() < 1e-12);
    }

    #[test]
    fn provider_parses_canonical_names_and_aliases() {
        for p in [
            Provider::AwsLambda,
            Provider::GoogleCloudFunctions,
            Provider::AzureFunctions,
            Provider::IbmCloudFunctions,
        ] {
            // Canonical name round-trips through FromStr.
            assert_eq!(p.canonical_name().parse::<Provider>().unwrap(), p);
        }
        assert_eq!("google".parse::<Provider>().unwrap(), Provider::GoogleCloudFunctions);
        assert_eq!("lambda".parse::<Provider>().unwrap(), Provider::AwsLambda);
        let err = "ec2".parse::<Provider>().unwrap_err().to_string();
        assert!(err.contains("unknown provider"), "{err}");
        assert!(err.contains("aws|gcf|google|azure|ibm"), "{err}");
    }
}
