//! The fleet simulator: N heterogeneous functions under one keep-alive
//! policy, with an optional fleet-wide concurrency cap or a
//! finite-resource cluster.
//!
//! Three execution strategies, chosen automatically:
//!
//! * **Sharded** (no fleet cap, no cluster): functions are independent,
//!   so each one runs on its own event queue and the fleet fans them
//!   across scoped threads with [`crate::sim::ensemble::run_indexed`].
//!   Function `i`'s evolution depends only on its spec and seed, so fleet
//!   output is **bit-identical for any thread count** — the same contract
//!   (and the same scheduling primitive) as the replication ensemble.
//! * **Coupled** (fleet cap set): the cap couples functions through
//!   admission — a cold start anywhere consumes shared capacity — so all
//!   functions interleave on one queue, single-threaded, with the shared
//!   [`super::engine::FleetGate`] deciding admission. Deterministic by
//!   construction (one thread, seq-tie-broken queue).
//! * **Clustered** (cluster configured): same single-queue interleaving
//!   as the coupled path, but admission asks the cluster's placement
//!   scheduler for a host with room — capacity is emergent from
//!   bin-packing over finite host memory/CPU, with memory-pressure
//!   eviction and host-drain windows on top. Deterministic by
//!   construction for any configured thread count (one thread,
//!   seq-tie-broken queue; `threads` is ignored).
//! * **Capacity domains** ([`FleetConfig::capacity_domains`] > 1): the
//!   capped/clustered paths shard into K independent domains — function
//!   `i` goes to domain `i % K`, each domain holding a proportional
//!   share of the fleet cap (or a contiguous block of cluster hosts)
//!   and running the single-queue coupled loop over its own functions
//!   on a scoped thread. Admission couples functions *within* a domain
//!   only (an explicit accuracy/scale trade, documented in DESIGN.md
//!   §Perf); each domain is itself single-threaded and seq-tie-broken,
//!   so the output is **bit-identical for any thread count**. `K = 1`
//!   is exactly the legacy coupled/clustered computation.
//!
//! With the cap absent the strategies produce identical per-function
//! results (functions never interact), which `coupled_matches_sharded_*`
//! pins below; a single-host unbounded cluster reproduces the uncapped
//! fleet bit-for-bit (pinned in `tests/engine_unification.rs`).

use super::engine::{FleetCapacity, FleetGate, FleetQueue, FunctionEngine, ScalableCapacity};
use super::policy::PolicySpec;
use crate::cluster::{ClusterConfig, ClusterState, ClusterUsage, HostDrain};
use crate::control::{ControlLoop, ControlReport, ControlSample, ControllerSpec};
use crate::cost::{estimate, CostEstimate, FunctionConfig, PricingTable};
use crate::sim::ensemble::run_indexed;
use crate::sim::event::Event;
use crate::sim::fault::FaultProfile;
use crate::sim::retry::RetryPolicy;
use crate::sim::results::SimResults;
use crate::sim::simulator::SimConfig;
use crate::sim::time::SimTime;
use crate::telemetry::{Observer, TelemetryRecorder};
use crate::workload::azure::SyntheticTrace;
use crate::workload::source::TraceSource;

// The per-function spec types live in the workload layer (the
// `TraceSource` seam yields them); re-exported here because the fleet is
// their primary consumer and the historical import path.
pub use crate::workload::source::{ArrivalMode, FunctionSpec};

/// One coupled capacity domain's output: per-function results, telemetry
/// recorders, cap rejections, and the domain's control-tick samples.
type CoupledDomainOut = (Vec<SimResults>, Vec<Option<TelemetryRecorder>>, u64, Vec<ControlSample>);

/// One clustered capacity domain's output: the coupled shape plus the
/// domain's cluster usage report.
type ClusteredDomainOut =
    (Vec<SimResults>, Vec<Option<TelemetryRecorder>>, u64, ClusterUsage, Vec<ControlSample>);

/// Fleet simulation input: the tenant mix, the keep-alive policy, and the
/// optional fleet-wide concurrency cap that couples functions.
#[derive(Clone)]
pub struct FleetConfig {
    pub functions: Vec<FunctionSpec>,
    pub policy: PolicySpec,
    /// Fleet-wide cap on concurrently live instances across *all*
    /// functions. `None` = uncoupled (sharded execution).
    pub fleet_max_concurrency: Option<usize>,
    /// Finite-resource cluster replacing the flat capacity counter: cold
    /// starts are placed onto hosts by the configured scheduler, each
    /// container charging its function's `memory_mb` (plus one core), so
    /// capacity is emergent from bin-packing. Mutually exclusive with
    /// `fleet_max_concurrency`; runs single-threaded like the coupled
    /// path (`threads` is ignored).
    pub cluster: Option<ClusterConfig>,
    /// Capacity domains for the capped/clustered paths: `K > 1` shards
    /// the fleet into K independent admission domains (function `i` →
    /// domain `i % K`, each with `cap/K` of the fleet cap or a
    /// contiguous `hosts/K` block of cluster hosts) that run on scoped
    /// threads. Trades global-cap fidelity for parallelism at extreme
    /// fleet sizes; `1` (the default) is the exact single-queue legacy
    /// path. Ignored by the uncapped (sharded) strategy, which is
    /// already embarrassingly parallel.
    pub capacity_domains: usize,
    /// Simulation horizon in seconds.
    pub horizon: f64,
    /// Warm-up window excluded from statistics.
    pub skip_initial: f64,
    /// Worker threads for the sharded path; 0 = one per available core.
    pub threads: usize,
    /// Provisioning lead time for prewarm events in seconds. `0.0`
    /// disables prewarming (bit-identical to the pre-prewarm engine); a
    /// positive lead arms the policy's head-percentile prewarm arm (the
    /// hybrid-histogram policy; fixed/stochastic policies predict nothing
    /// and behave as if disabled).
    pub prewarm_lead: f64,
    /// Fault profile applied to every function (each engine draws from its
    /// own seed-derived fault RNG lane, so the sharded thread-count
    /// invariance holds). [`FaultProfile::disabled`] is bit-identical to
    /// the fault-free engines.
    pub fault: FaultProfile,
    /// Retry policy clients apply to failed/timed-out/rejected requests.
    pub retry: RetryPolicy,
    /// Telemetry sampling interval in seconds: `Some(interval)` attaches a
    /// recording [`Observer`] to every function (spans always; an interval
    /// `<= 0` records spans only) and fills [`FleetResults::telemetry`].
    /// `None` disables capture entirely — results stay bit-identical
    /// either way (capture draws no RNG and schedules no events).
    pub telemetry: Option<f64>,
    /// Autoscaling controller moving the capacity at simulated time
    /// (`crate::control`): the flat fleet cap on the coupled path, the
    /// host set on the clustered path. `None` (the default) schedules no
    /// control ticks and is bit-identical to the uncontrolled engines;
    /// the uncapped sharded path has no capacity to actuate and ignores
    /// it. With `capacity_domains` > 1 each domain runs its own
    /// controller over a proportional share of the capacity bounds,
    /// exactly like cap striping.
    pub controller: Option<ControllerSpec>,
}

impl FleetConfig {
    /// Fleet of explicit per-function configs (each keeps its own seed).
    /// Horizon and warm-up skip come from the first config.
    pub fn from_sim_configs(cfgs: &[SimConfig], policy: PolicySpec) -> Self {
        assert!(!cfgs.is_empty());
        let functions = cfgs
            .iter()
            .enumerate()
            .map(|(i, c)| FunctionSpec::from_sim_config(format!("fn-{i:04}"), c))
            .collect();
        FleetConfig {
            functions,
            policy,
            fleet_max_concurrency: None,
            cluster: None,
            capacity_domains: 1,
            horizon: cfgs[0].horizon,
            skip_initial: cfgs[0].skip_initial,
            threads: 0,
            prewarm_lead: 0.0,
            fault: FaultProfile::disabled(),
            retry: RetryPolicy::none(),
            telemetry: None,
            controller: None,
        }
    }

    /// Fleet from any [`TraceSource`]: synthetic mix, ingested Azure
    /// dataset, explicit specs, or a recorded workload. Per-function seeds
    /// derive from `root_seed` via SplitMix64 (two streams per function:
    /// arrival generation and service draws), so the whole fleet is
    /// described by `(source, horizon, root_seed)` and is
    /// shard-count-invariant. Arrivals stream lazily — nothing is
    /// materialized, so resident memory no longer grows with
    /// horizon × fleet size.
    pub fn from_source(
        source: &TraceSource,
        horizon: f64,
        skip_initial: f64,
        root_seed: u64,
        policy: PolicySpec,
    ) -> Self {
        let functions = source.function_specs(root_seed);
        assert!(!functions.is_empty(), "trace source has no functions");
        FleetConfig {
            functions,
            policy,
            fleet_max_concurrency: None,
            cluster: None,
            capacity_domains: 1,
            horizon,
            skip_initial,
            threads: 0,
            prewarm_lead: 0.0,
            fault: FaultProfile::disabled(),
            retry: RetryPolicy::none(),
            telemetry: None,
            controller: None,
        }
    }

    /// Fleet from a synthetic Azure-style tenant mix — the
    /// [`TraceSource::Synthetic`] case of [`from_source`](Self::from_source).
    /// Bit-identical to the historical eager materialization: the
    /// streaming generator draws the same RNG stream per function.
    pub fn from_trace(
        trace: &SyntheticTrace,
        horizon: f64,
        skip_initial: f64,
        root_seed: u64,
        policy: PolicySpec,
    ) -> Self {
        Self::from_source(
            &TraceSource::Synthetic(trace.clone()),
            horizon,
            skip_initial,
            root_seed,
            policy,
        )
    }

    pub fn with_policy(mut self, policy: PolicySpec) -> Self {
        self.policy = policy;
        self
    }

    pub fn with_fleet_cap(mut self, cap: usize) -> Self {
        self.fleet_max_concurrency = Some(cap);
        self
    }

    /// Replace the flat capacity counter with a finite-resource cluster:
    /// cold starts are bin-packed onto hosts by the cluster's scheduler.
    pub fn with_cluster(mut self, cluster: ClusterConfig) -> Self {
        self.cluster = Some(cluster);
        self
    }

    pub fn with_threads(mut self, threads: usize) -> Self {
        self.threads = threads;
        self
    }

    /// Shard the capped/clustered paths into `k` independent capacity
    /// domains (see [`FleetConfig::capacity_domains`]). `1` restores the
    /// exact single-queue legacy path.
    pub fn with_capacity_domains(mut self, k: usize) -> Self {
        self.capacity_domains = k;
        self
    }

    /// Enable prewarm (provisioning-lead) events: instances provision
    /// `lead` seconds before the policy's predicted arrivals. 0 disables.
    pub fn with_prewarm_lead(mut self, lead: f64) -> Self {
        self.prewarm_lead = lead;
        self
    }

    /// Apply a fault profile to every function in the fleet.
    pub fn with_fault(mut self, fault: FaultProfile) -> Self {
        self.fault = fault;
        self
    }

    /// Apply a client retry policy to every function in the fleet.
    pub fn with_retry(mut self, retry: RetryPolicy) -> Self {
        self.retry = retry;
        self
    }

    /// Enable telemetry capture with the given internal-state sampling
    /// interval in seconds (an interval `<= 0` records spans only).
    pub fn with_telemetry(mut self, interval: f64) -> Self {
        self.telemetry = Some(interval);
        self
    }

    /// Attach an autoscaling controller (see [`ControllerSpec`] and
    /// [`FleetConfig::controller`]).
    pub fn with_controller(mut self, spec: ControllerSpec) -> Self {
        self.controller = Some(spec);
        self
    }

    fn build_engine(&self, i: usize) -> FunctionEngine {
        let mut engine = FunctionEngine::new(
            i as u32,
            &self.functions[i],
            self.policy.build(),
            self.skip_initial,
            self.prewarm_lead,
            self.horizon,
            self.fault.clone(),
            self.retry.clone(),
        );
        if let Some(interval) = self.telemetry {
            engine.set_observer(Observer::recording(i as u32, interval));
        }
        engine
    }

    /// Run the fleet to the horizon.
    pub fn run(&self) -> FleetResults {
        assert!(!self.functions.is_empty(), "fleet has no functions");
        assert!(
            self.cluster.is_none() || self.fleet_max_concurrency.is_none(),
            "cluster and fleet_max_concurrency are mutually exclusive capacity models"
        );
        let (per_function, recorders, cap_rejections, cluster_usage, control_samples) =
            match (&self.cluster, self.fleet_max_concurrency) {
                (Some(cl), _) => {
                    let (runs, recs, rejections, usage, ctl) = self.run_clustered(cl);
                    (runs, recs, rejections, Some(usage), ctl)
                }
                (None, Some(cap)) => {
                    let (runs, recs, rejections, ctl) = self.run_coupled(cap);
                    (runs, recs, rejections, None, ctl)
                }
                (None, None) => {
                    let (runs, recs) = self.run_sharded();
                    (runs, recs, 0, None, Vec::new())
                }
            };
        let names = self.functions.iter().map(|f| f.name.clone()).collect();
        let aggregate = FleetAggregate::from_runs(&per_function, cap_rejections, cluster_usage);
        // Recorders come back in function-index order regardless of the
        // shard/thread count, so the recorded bytes are deterministic.
        let telemetry = self
            .telemetry
            .is_some()
            .then(|| recorders.into_iter().map(Option::unwrap_or_default).collect());
        // The sharded path has no shared capacity to actuate, so a
        // configured controller reports nothing there.
        let control = match &self.controller {
            Some(spec) if self.cluster.is_some() || self.fleet_max_concurrency.is_some() => {
                Some(ControlReport::from_samples(control_samples, spec))
            }
            _ => None,
        };
        FleetResults { names, per_function, aggregate, telemetry, control }
    }

    /// Domains actually used for a shared resource of `resources` units
    /// (the fleet cap or the host count): the configured count clamped so
    /// every domain owns at least one function and one unit of capacity.
    fn effective_domains(&self, resources: usize) -> usize {
        self.capacity_domains.max(1).min(self.functions.len()).min(resources.max(1))
    }

    /// Independent functions, one engine per shard job.
    fn run_sharded(&self) -> (Vec<SimResults>, Vec<Option<TelemetryRecorder>>) {
        let horizon = SimTime::from_secs(self.horizon);
        let runs = run_indexed(self.functions.len(), self.threads, |i| {
            let mut engine = self.build_engine(i);
            let mut queue = FleetQueue::with_capacity(expected_fleet_events(
                std::iter::once(&self.functions[i]),
                self.horizon,
            ));
            let mut gate = FleetGate::unbounded();
            engine.schedule_first_arrival(&mut queue);
            queue.schedule(horizon, 0, Event::Horizon);
            while let Some((t, _f, ev)) = queue.pop() {
                engine.maybe_start_stats(t);
                engine.set_now(t);
                engine.sample_tick(None);
                if matches!(ev, Event::Horizon) {
                    break;
                }
                engine.handle_event(&mut queue, &mut FleetCapacity::Gate(&mut gate), ev);
            }
            let results = engine.finish(horizon);
            (results, engine.take_recorder())
        });
        runs.into_iter().unzip()
    }

    /// Cap-coupled functions interleaved on one queue. With
    /// `capacity_domains` > 1 the fleet splits into K domains, each
    /// coupling its stride of functions through a proportional cap share
    /// (`cap/K`, remainder to the lowest domains) on its own queue and
    /// scoped thread; results come back in global function order and cap
    /// rejections sum across domains.
    fn run_coupled(&self, cap: usize) -> CoupledDomainOut {
        let k = self.effective_domains(cap);
        if k <= 1 {
            return self.run_coupled_domain(0, 1, cap);
        }
        let domains = run_indexed(k, self.threads, |d| {
            let share = cap / k + usize::from(d < cap % k);
            self.run_coupled_domain(d, k, share)
        });
        let n = self.functions.len();
        let mut runs: Vec<Option<SimResults>> = (0..n).map(|_| None).collect();
        let mut recorders: Vec<Option<TelemetryRecorder>> = (0..n).map(|_| None).collect();
        let mut rejections = 0u64;
        let mut samples = Vec::new();
        for (d, (druns, drecs, drej, dctl)) in domains.into_iter().enumerate() {
            for (j, (r, rec)) in druns.into_iter().zip(drecs).enumerate() {
                runs[d + j * k] = Some(r);
                recorders[d + j * k] = rec;
            }
            rejections += drej;
            // Domain-order concatenation keeps the control trace
            // thread-count-invariant.
            samples.extend(dctl);
        }
        let runs = runs.into_iter().map(|r| r.expect("stride covers every function")).collect();
        (runs, recorders, rejections, samples)
    }

    /// One capacity domain of the coupled path: the single-queue,
    /// single-threaded loop over the global function stride
    /// `{domain, domain + k, ...}` with its own admission gate. `k = 1`
    /// is the entire fleet — the exact legacy coupled computation.
    fn run_coupled_domain(&self, domain: usize, k: usize, cap: usize) -> CoupledDomainOut {
        let horizon = SimTime::from_secs(self.horizon);
        let indices: Vec<usize> = (domain..self.functions.len()).step_by(k).collect();
        let mut engines: Vec<FunctionEngine> =
            indices.iter().map(|&i| self.build_engine(i)).collect();
        let mut queue = FleetQueue::with_capacity(expected_fleet_events(
            indices.iter().map(|&i| &self.functions[i]),
            self.horizon,
        ));
        for engine in engines.iter_mut() {
            engine.schedule_first_arrival(&mut queue);
        }
        queue.schedule(horizon, 0, Event::Horizon);
        let mut gate = FleetGate::capped(cap);
        // Control state lives with this domain's single-queue loop: ticks
        // are tagged with the domain index (a global function id in this
        // stride) and intercepted below before any engine sees them. No
        // controller -> no tick ever scheduled -> bit-identical run.
        let mut control = self.controller.as_ref().map(|spec| ControlLoop::new(spec, domain, k));
        if let Some(ctl) = &control {
            let first = ctl.first_tick();
            if first < self.horizon {
                queue.schedule(SimTime::from_secs(first), domain as u32, Event::ControlTick);
            }
        }
        while let Some((t, f, ev)) = queue.pop() {
            if matches!(ev, Event::Horizon) {
                break;
            }
            // Queue tags are *global* function indices; this domain owns
            // the stride f ≡ domain (mod k), so the local slot is f / k.
            debug_assert_eq!(f as usize % k, domain);
            if matches!(ev, Event::ControlTick) {
                let ctl = control.as_mut().expect("control tick without a controller");
                let now = t.as_secs();
                let (observed, capacity) = gate.observe();
                let desired = ctl.tick(now, observed, capacity);
                if desired != capacity {
                    gate.scale_to(desired, t);
                }
                let next = now + ctl.tick_interval;
                if next < self.horizon {
                    queue.schedule(SimTime::from_secs(next), domain as u32, Event::ControlTick);
                }
                continue;
            }
            let engine = &mut engines[f as usize / k];
            engine.maybe_start_stats(t);
            engine.set_now(t);
            engine.sample_tick(Some(gate.headroom()));
            engine.handle_event(&mut queue, &mut FleetCapacity::Gate(&mut gate), ev);
        }
        let mut runs = Vec::with_capacity(engines.len());
        let mut recorders = Vec::with_capacity(engines.len());
        for engine in engines.iter_mut() {
            runs.push(engine.finish(horizon));
            // Flush samples due in the final (last event, horizon] window
            // — `finish` advanced the engine clock to the horizon.
            engine.sample_tick(Some(gate.headroom()));
            recorders.push(engine.take_recorder());
        }
        let samples = control.map(|c| c.samples).unwrap_or_default();
        (runs, recorders, gate.cap_rejections, samples)
    }

    /// Cluster-coupled functions: the coupled path's single-queue
    /// interleaving, with admission decided by the cluster's placement
    /// scheduler over finite hosts instead of a flat counter. With
    /// `capacity_domains` > 1 the fleet splits into K domains, each
    /// bin-packing its stride of functions onto a contiguous block of
    /// `hosts/K` hosts (remainder to the lowest domains); per-domain
    /// utilization reports concatenate back into global host order.
    fn run_clustered(&self, cl: &ClusterConfig) -> ClusteredDomainOut {
        let k = self.effective_domains(cl.hosts);
        if k <= 1 {
            return self.run_clustered_domain(0, 1, cl.clone());
        }
        let domains = run_indexed(k, self.threads, |d| {
            // Contiguous host blocks: domain d owns global hosts
            // [offset, offset + share). Drain windows inside the block
            // remap to block-local indices; windows on other domains'
            // hosts apply in their own domain.
            let share = cl.hosts / k + usize::from(d < cl.hosts % k);
            let offset: usize =
                (0..d).map(|p| cl.hosts / k + usize::from(p < cl.hosts % k)).sum();
            let mut sub = cl.clone();
            sub.hosts = share;
            sub.drains = cl
                .drains
                .iter()
                .filter(|w| w.host >= offset && w.host < offset + share)
                .map(|w| HostDrain { host: w.host - offset, start: w.start, end: w.end })
                .collect();
            self.run_clustered_domain(d, k, sub)
        });
        let n = self.functions.len();
        let mut runs: Vec<Option<SimResults>> = (0..n).map(|_| None).collect();
        let mut recorders: Vec<Option<TelemetryRecorder>> = (0..n).map(|_| None).collect();
        let mut rejections = 0u64;
        let mut usage = ClusterUsage::default();
        let mut samples = Vec::new();
        for (d, (druns, drecs, drej, du, dctl)) in domains.into_iter().enumerate() {
            for (j, (r, rec)) in druns.into_iter().zip(drecs).enumerate() {
                runs[d + j * k] = Some(r);
                recorders[d + j * k] = rec;
            }
            rejections += drej;
            usage.placement_failures += du.placement_failures;
            usage.evictions += du.evictions;
            // Domain blocks are contiguous, so domain-order concatenation
            // is global host order.
            usage.host_utilization.extend(du.host_utilization);
            samples.extend(dctl);
        }
        let runs = runs.into_iter().map(|r| r.expect("stride covers every function")).collect();
        (runs, recorders, rejections, usage, samples)
    }

    /// One capacity domain of the clustered path: the single-queue loop
    /// over the global function stride `{domain, domain + k, ...}`
    /// against its own (already host-subsetted) cluster. `k = 1` is the
    /// entire fleet on the full cluster — the exact legacy computation.
    fn run_clustered_domain(&self, domain: usize, k: usize, cl: ClusterConfig) -> ClusteredDomainOut {
        let horizon = SimTime::from_secs(self.horizon);
        let indices: Vec<usize> = (domain..self.functions.len()).step_by(k).collect();
        let mut engines: Vec<FunctionEngine> =
            indices.iter().map(|&i| self.build_engine(i)).collect();
        let mut queue = FleetQueue::with_capacity(expected_fleet_events(
            indices.iter().map(|&i| &self.functions[i]),
            self.horizon,
        ));
        for engine in engines.iter_mut() {
            engine.schedule_first_arrival(&mut queue);
        }
        queue.schedule(horizon, 0, Event::Horizon);
        // Allocation stacks are indexed by *global* function id (the
        // engines tag placements with their global index), so size the
        // state for the whole fleet even when the domain owns a stride.
        let mut cluster = ClusterState::new(&cl, self.functions.len());
        // Controller state (see run_coupled_domain): capacity units here
        // are hosts — active plus still-provisioning.
        let mut control = self.controller.as_ref().map(|spec| ControlLoop::new(spec, domain, k));
        let mut pending: Vec<f64> = Vec::new();
        if let Some(ctl) = &control {
            let first = ctl.first_tick();
            if first < self.horizon {
                queue.schedule(SimTime::from_secs(first), domain as u32, Event::ControlTick);
            }
        }
        while let Some((t, f, ev)) = queue.pop() {
            if matches!(ev, Event::Horizon) {
                break;
            }
            debug_assert_eq!(f as usize % k, domain);
            if matches!(ev, Event::ControlTick) {
                let ctl = control.as_mut().expect("control tick without a controller");
                let now = t.as_secs();
                // Advance only the accounting clock: recomputing drain
                // cordons at tick times would shift window boundaries and
                // break the inert-controller bit-identity contract.
                cluster.set_now(now);
                // Hosts whose provisioning delay elapsed join warm before
                // this tick observes capacity.
                pending.retain(|&ready| {
                    if ready <= now {
                        cluster.add_host();
                        false
                    } else {
                        true
                    }
                });
                let mut scaler = ClusterScaler {
                    cluster: &mut cluster,
                    engines: &mut engines,
                    k,
                    pending: &mut pending,
                    delay: ctl.provision_delay,
                    eviction: cl.eviction,
                };
                let (observed, capacity) = scaler.observe();
                let desired = ctl.tick(now, observed, capacity);
                if desired != capacity {
                    scaler.scale_to(desired, t);
                }
                let next = now + ctl.tick_interval;
                if next < self.horizon {
                    queue.schedule(SimTime::from_secs(next), domain as u32, Event::ControlTick);
                }
                continue;
            }
            let local = f as usize / k;
            // Drain windows opening at or before this event cordon their
            // host and (with eviction on) reclaim its idle containers.
            for host in cluster.advance_to(t.as_secs()) {
                if cl.eviction {
                    Self::drain_host(&mut engines, &mut cluster, k, host, t);
                }
            }
            // Evict-on-demand: if this event may need a cold placement
            // and no host currently has room for the function's
            // footprint, reclaim idle containers first — real platforms
            // evict idle containers to make room rather than reject.
            if cl.eviction
                && matches!(ev, Event::Arrival | Event::RetryArrival { .. } | Event::Provision)
                && engines[local].idle_count() == 0
            {
                let need = engines[local].memory_mb();
                if !cluster.any_host_fits(need) {
                    Self::relieve_pressure(&mut engines, &mut cluster, k, need, t);
                }
            }
            let engine = &mut engines[local];
            engine.maybe_start_stats(t);
            engine.set_now(t);
            engine.sample_tick(Some(cluster.headroom()));
            engine.handle_event(&mut queue, &mut FleetCapacity::Cluster(&mut cluster), ev);
            // A placement failure inside the event (e.g. the second
            // request of a batch) raises pressure; relieve it so the
            // *next* placement finds room.
            if let Some(need) = cluster.take_pressure() {
                if cl.eviction {
                    Self::relieve_pressure(&mut engines, &mut cluster, k, need, t);
                }
            }
        }
        let mut runs = Vec::with_capacity(engines.len());
        let mut recorders = Vec::with_capacity(engines.len());
        for engine in engines.iter_mut() {
            runs.push(engine.finish(horizon));
            // Flush samples due in the final (last event, horizon] window
            // — `finish` advanced the engine clock to the horizon.
            engine.sample_tick(Some(cluster.headroom()));
            recorders.push(engine.take_recorder());
        }
        let rejections = cluster.gate_rejections();
        let usage = cluster.usage(self.horizon);
        let samples = control.map(|c| c.samples).unwrap_or_default();
        (runs, recorders, rejections, usage, samples)
    }

    /// Evict every idle container from a newly cordoned host. Busy
    /// containers keep running and drain naturally — the same
    /// shrink-don't-kill semantics as capacity degradation.
    fn drain_host(
        engines: &mut [FunctionEngine],
        cluster: &mut ClusterState,
        k: usize,
        host: usize,
        t: SimTime,
    ) {
        loop {
            let mut progressed = false;
            for func in cluster.functions_on(host) {
                let engine = &mut engines[func as usize / k];
                if engine.idle_count() == 0 {
                    continue;
                }
                engine.maybe_start_stats(t);
                engine.set_now(t);
                cluster.pin_release(host);
                let evicted = engine.evict_idle(&mut FleetCapacity::Cluster(&mut *cluster), 1);
                cluster.clear_pin();
                if evicted > 0 {
                    progressed = true;
                }
            }
            if !progressed {
                break;
            }
        }
    }

    /// Memory-pressure relief: evict idle containers (oldest first, in
    /// ascending function order) from the host closest to fitting the
    /// failed `need` footprint until it fits or no evictable container
    /// remains there. Containers are fungible per function, so the
    /// placement stack decides *whose* resources come off the host while
    /// each engine decides *which* physical instance dies.
    fn relieve_pressure(
        engines: &mut [FunctionEngine],
        cluster: &mut ClusterState,
        k: usize,
        need: f64,
        t: SimTime,
    ) {
        let Some(target) = cluster.pressure_target() else {
            return;
        };
        while !cluster.host_fits(target, need) {
            let mut progressed = false;
            for func in cluster.functions_on(target) {
                let engine = &mut engines[func as usize / k];
                if engine.idle_count() == 0 {
                    continue;
                }
                engine.maybe_start_stats(t);
                engine.set_now(t);
                cluster.pin_release(target);
                let evicted = engine.evict_idle(&mut FleetCapacity::Cluster(&mut *cluster), 1);
                cluster.clear_pin();
                if evicted > 0 {
                    progressed = true;
                    break;
                }
            }
            if !progressed {
                break;
            }
        }
    }
}

/// Cluster backend of the [`ScalableCapacity`] seam: capacity units are
/// hosts — active plus still inside their provisioning delay. Scale-out
/// queues a pending host that joins warm after the delay elapses (at the
/// tick that observes it); scale-in cancels pending hosts first (newest
/// ready time), then retires live hosts through the cordon/evict
/// machinery so busy containers drain naturally.
struct ClusterScaler<'a> {
    cluster: &'a mut ClusterState,
    engines: &'a mut Vec<FunctionEngine>,
    k: usize,
    pending: &'a mut Vec<f64>,
    delay: f64,
    eviction: bool,
}

impl ScalableCapacity for ClusterScaler<'_> {
    fn observe(&self) -> (f64, u64) {
        let capacity = self.cluster.active_hosts() + self.pending.len() as u64;
        (self.cluster.memory_utilization(), capacity)
    }

    fn scale_to(&mut self, desired: u64, now: SimTime) {
        let current = self.cluster.active_hosts() + self.pending.len() as u64;
        if desired > current {
            for _ in 0..desired - current {
                self.pending.push(now.as_secs() + self.delay);
            }
            return;
        }
        let mut shrink = current - desired;
        while shrink > 0 && self.pending.pop().is_some() {
            shrink -= 1;
        }
        while shrink > 0 {
            let Some(host) = self.cluster.retire_target() else {
                break;
            };
            self.cluster.retire_host(host);
            if self.eviction {
                FleetConfig::drain_host(self.engines, self.cluster, self.k, host, now);
            }
            shrink -= 1;
        }
    }
}

/// Expected concurrently pending events for the given functions: one
/// arrival chain per function plus, for each, its mean arrival rate ×
/// the typical event residency (mean warm service + the canonical 600 s
/// keep-alive window, which bounds how long Departure/Expiration events
/// sit in the queue). Sizes the calendar queue's bucket array so steady
/// state starts near one event per bucket instead of resizing up from
/// the floor — the fleet analogue of
/// `sim::simulator::expected_pending_events`, derived from the workload
/// instead of a fixed constant.
fn expected_fleet_events<'a>(
    specs: impl Iterator<Item = &'a FunctionSpec>,
    horizon: f64,
) -> usize {
    let mut est = 0.0f64;
    for f in specs {
        let rate = match &f.arrival {
            ArrivalMode::Process(p) => {
                let gap = p.mean().unwrap_or(0.0);
                if gap > 0.0 {
                    1.0 / gap
                } else {
                    0.0
                }
            }
            ArrivalMode::Trace(times) => {
                if horizon > 0.0 {
                    times.len() as f64 / horizon
                } else {
                    0.0
                }
            }
            ArrivalMode::Streaming(spec) => spec.shape.mean_rate(),
        };
        let window = f.warm_service.mean().unwrap_or(1.0).max(0.0) + 600.0;
        est += 1.0;
        if rate.is_finite() && rate > 0.0 {
            est += rate * window;
        }
    }
    if est.is_finite() && est > 0.0 {
        (est as usize).clamp(64, 1 << 20)
    } else {
        64
    }
}

/// Fleet-level rollup of the per-function results.
///
/// Request counters and time-weighted level averages sum exactly across
/// functions (accumulated in function-index order, so the rollup is as
/// shard-count-invariant as the per-function results). Response means and
/// P² percentiles are merged request-weighted: exact for the means,
/// approximate at the mixture level for the percentiles (each function's
/// P² estimate is exact, but a weighted mean of per-function quantiles is
/// not the quantile of the pooled distribution).
#[derive(Debug, Clone)]
pub struct FleetAggregate {
    pub functions: usize,
    pub measured_time: f64,
    pub total_requests: u64,
    pub cold_requests: u64,
    pub warm_requests: u64,
    pub rejected_requests: u64,
    /// Rejections attributable to fleet-wide capacity alone (the fleet
    /// cap, or failed cluster placement; 0 when uncapped).
    pub cap_rejections: u64,
    pub cold_start_prob: f64,
    pub rejection_prob: f64,
    pub avg_server_count: f64,
    pub avg_running_count: f64,
    pub avg_idle_count: f64,
    pub wasted_capacity: f64,
    pub instances_created: u64,
    pub instances_expired: u64,
    /// Request-weighted mean lifespan of expired instances.
    pub avg_lifespan: f64,
    pub avg_response_time: f64,
    pub response_p50: f64,
    pub response_p95: f64,
    pub response_p99: f64,
    pub billed_instance_seconds: f64,
    pub observed_arrival_rate: f64,
    /// Prewarm (provisioning-lead) instances started across the fleet
    /// (0 unless [`FleetConfig::prewarm_lead`] is positive).
    pub prewarm_starts: u64,
    /// Total lifespan of prewarmed instances that expired unused.
    pub wasted_prewarm_seconds: f64,
    /// Transient execution failures summed across the fleet.
    pub failed_requests: u64,
    /// Executions cut off at the fault profile's timeout, fleet-wide.
    pub timeout_requests: u64,
    /// Admitted cold starts whose provisioning failed, fleet-wide.
    pub coldstart_failures: u64,
    /// Retry re-arrivals across the fleet (included in `total_requests`).
    pub retry_attempts: u64,
    /// Failures that exhausted max-attempts or the retry budget.
    pub retry_exhausted: u64,
    /// Billed busy-seconds spent on failed/timed-out executions.
    pub wasted_work_seconds: f64,
    /// Fleet-wide successful responses per second of measured time.
    pub goodput: f64,
    /// Cluster placement attempts (cold starts and prewarms) no host
    /// could fit (0 without a cluster).
    pub placement_failures: u64,
    /// Idle containers force-evicted by cluster memory pressure or host
    /// drains (0 without a cluster).
    pub evictions: u64,
    /// Per-host time-averaged memory utilization over the run (empty
    /// without a cluster).
    pub host_utilization: Vec<f64>,
}

impl FleetAggregate {
    fn from_runs(
        runs: &[SimResults],
        cap_rejections: u64,
        cluster: Option<ClusterUsage>,
    ) -> FleetAggregate {
        let cluster = cluster.unwrap_or_default();
        let measured_time = runs.first().map(|r| r.measured_time).unwrap_or(0.0);
        let mut total = 0u64;
        let mut cold = 0u64;
        let mut warm = 0u64;
        let mut rejected = 0u64;
        let mut created = 0u64;
        let mut expired = 0u64;
        let mut avg_server = 0.0;
        let mut avg_running = 0.0;
        let mut billed = 0.0;
        // Request-weighted response merges, skipping empty functions whose
        // OnlineStats/P² report NaN.
        let mut resp_w = 0.0;
        let mut resp = 0.0;
        let mut p50 = 0.0;
        let mut p95 = 0.0;
        let mut p99 = 0.0;
        let mut life_w = 0.0;
        let mut life = 0.0;
        let mut prewarms = 0u64;
        let mut prewarm_waste = 0.0;
        let mut failed = 0u64;
        let mut timeouts = 0u64;
        let mut cs_failures = 0u64;
        let mut retries = 0u64;
        let mut exhausted = 0u64;
        let mut wasted_work = 0.0;
        for r in runs {
            total += r.total_requests;
            cold += r.cold_requests;
            warm += r.warm_requests;
            rejected += r.rejected_requests;
            created += r.instances_created;
            expired += r.instances_expired;
            avg_server += r.avg_server_count;
            avg_running += r.avg_running_count;
            billed += r.billed_instance_seconds;
            prewarms += r.prewarm_starts;
            prewarm_waste += r.wasted_prewarm_seconds;
            failed += r.failed_requests;
            timeouts += r.timeout_requests;
            cs_failures += r.coldstart_failures;
            retries += r.retry_attempts;
            exhausted += r.retry_exhausted;
            wasted_work += r.wasted_work_seconds;
            let served = (r.cold_requests + r.warm_requests) as f64;
            if served > 0.0 {
                resp_w += served;
                resp += served * r.avg_response_time;
                p50 += served * r.response_p50;
                p95 += served * r.response_p95;
                p99 += served * r.response_p99;
            }
            if r.instances_expired > 0 {
                life_w += r.instances_expired as f64;
                life += r.instances_expired as f64 * r.avg_lifespan;
            }
        }
        let served = cold + warm;
        let avg_idle = avg_server - avg_running;
        FleetAggregate {
            functions: runs.len(),
            measured_time,
            total_requests: total,
            cold_requests: cold,
            warm_requests: warm,
            rejected_requests: rejected,
            cap_rejections,
            cold_start_prob: if served > 0 { cold as f64 / served as f64 } else { 0.0 },
            rejection_prob: if total > 0 { rejected as f64 / total as f64 } else { 0.0 },
            avg_server_count: avg_server,
            avg_running_count: avg_running,
            avg_idle_count: avg_idle,
            wasted_capacity: if avg_server > 0.0 { avg_idle / avg_server } else { 0.0 },
            instances_created: created,
            instances_expired: expired,
            avg_lifespan: if life_w > 0.0 { life / life_w } else { f64::NAN },
            avg_response_time: if resp_w > 0.0 { resp / resp_w } else { f64::NAN },
            response_p50: if resp_w > 0.0 { p50 / resp_w } else { f64::NAN },
            response_p95: if resp_w > 0.0 { p95 / resp_w } else { f64::NAN },
            response_p99: if resp_w > 0.0 { p99 / resp_w } else { f64::NAN },
            billed_instance_seconds: billed,
            observed_arrival_rate: if measured_time > 0.0 {
                total as f64 / measured_time
            } else {
                0.0
            },
            prewarm_starts: prewarms,
            wasted_prewarm_seconds: prewarm_waste,
            failed_requests: failed,
            timeout_requests: timeouts,
            coldstart_failures: cs_failures,
            retry_attempts: retries,
            retry_exhausted: exhausted,
            wasted_work_seconds: wasted_work,
            goodput: if measured_time > 0.0 {
                served.saturating_sub(failed + timeouts) as f64 / measured_time
            } else {
                0.0
            },
            placement_failures: cluster.placement_failures,
            evictions: cluster.evictions,
            host_utilization: cluster.host_utilization,
        }
    }

    /// Fraction of fleet arrivals that got a successful response
    /// (1.0 when nothing arrived).
    pub fn success_rate(&self) -> f64 {
        if self.total_requests == 0 {
            return 1.0;
        }
        let ok = (self.cold_requests + self.warm_requests)
            .saturating_sub(self.failed_requests + self.timeout_requests);
        ok as f64 / self.total_requests as f64
    }

    /// Two-column fleet report in the Table-1 style.
    pub fn to_table(&self) -> String {
        let mut rows: Vec<(&str, String)> = vec![
            ("Functions", format!("{}", self.functions)),
            ("*Cold Start Probability", format!("{:.4} %", self.cold_start_prob * 100.0)),
            ("*Rejection Probability", format!("{:.4} %", self.rejection_prob * 100.0)),
            ("  of which fleet-cap", format!("{}", self.cap_rejections)),
            ("*Average Server Count", format!("{:.4}", self.avg_server_count)),
            ("*Average Running Servers", format!("{:.4}", self.avg_running_count)),
            ("*Average Idle Count", format!("{:.4}", self.avg_idle_count)),
            ("*Average Wasted Capacity", format!("{:.4} %", self.wasted_capacity * 100.0)),
            ("*Average Response Time", format!("{:.4} s", self.avg_response_time)),
            ("Response P95 (merged)", format!("{:.4} s", self.response_p95)),
            ("Billed instance-seconds", format!("{:.1}", self.billed_instance_seconds)),
            ("Prewarm starts", format!("{}", self.prewarm_starts)),
            ("Wasted prewarm time", format!("{:.1} s", self.wasted_prewarm_seconds)),
            ("Observed arrival rate", format!("{:.4} req/s", self.observed_arrival_rate)),
            ("Requests (total/cold/warm/rej)", format!(
                "{}/{}/{}/{}",
                self.total_requests, self.cold_requests, self.warm_requests,
                self.rejected_requests
            )),
            ("*Success Rate", format!("{:.4} %", self.success_rate() * 100.0)),
            ("*Goodput", format!("{:.4} req/s", self.goodput)),
            ("Failures (transient/timeout/coldstart)", format!(
                "{}/{}/{}",
                self.failed_requests, self.timeout_requests, self.coldstart_failures
            )),
            ("Retries (attempts/exhausted)", format!(
                "{}/{}",
                self.retry_attempts, self.retry_exhausted
            )),
            ("Wasted Work", format!("{:.4} s", self.wasted_work_seconds)),
        ];
        if !self.host_utilization.is_empty() {
            let hosts = self.host_utilization.len();
            let avg_util = self.host_utilization.iter().sum::<f64>() / hosts as f64;
            rows.push(("Cluster hosts", format!("{hosts}")));
            rows.push(("Cluster avg memory utilization", format!("{:.4} %", avg_util * 100.0)));
            rows.push(("Cluster placement failures", format!("{}", self.placement_failures)));
            rows.push(("Cluster evictions", format!("{}", self.evictions)));
        }
        let w = rows.iter().map(|(k, _)| k.len()).max().unwrap_or(0);
        let mut s = String::new();
        for (k, v) in rows {
            s.push_str(&format!("{k:<w$}  {v}\n"));
        }
        s
    }
}

/// Results of one fleet run: per-function [`SimResults`] (index-aligned
/// with [`FleetConfig::functions`]) plus the fleet rollup.
#[derive(Debug, Clone)]
pub struct FleetResults {
    pub names: Vec<String>,
    pub per_function: Vec<SimResults>,
    pub aggregate: FleetAggregate,
    /// Per-function telemetry recordings, index-aligned with `names`.
    /// `Some` exactly when [`FleetConfig::telemetry`] was set.
    pub telemetry: Option<Vec<TelemetryRecorder>>,
    /// Autoscaling control report. `Some` exactly when
    /// [`FleetConfig::controller`] was set on a capped or clustered run
    /// (the sharded path has no capacity to actuate).
    pub control: Option<ControlReport>,
}

/// Fleet cost rollup: per-function estimates plus the exact sum.
#[derive(Debug, Clone)]
pub struct FleetCostReport {
    pub per_function: Vec<CostEstimate>,
    pub total: CostEstimate,
}

/// Price a fleet run through a provider's [`PricingTable`]: each function
/// billed at its own `memory_mb`, summed into the fleet total. With no
/// fleet cap the per-function estimates equal those of solo
/// `ServerlessSimulator` runs (regression-tested in `tests/cost_properties`).
pub fn fleet_cost(
    cfg: &FleetConfig,
    results: &FleetResults,
    pricing: &PricingTable,
) -> FleetCostReport {
    assert_eq!(cfg.functions.len(), results.per_function.len());
    let mut per_function = Vec::with_capacity(results.per_function.len());
    let mut total = CostEstimate::zero(results.aggregate.measured_time);
    for (spec, r) in cfg.functions.iter().zip(&results.per_function) {
        let est = estimate(r, &FunctionConfig::new(spec.memory_mb), pricing);
        total.accumulate(&est);
        per_function.push(est);
    }
    FleetCostReport { per_function, total }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fleet::policy::PolicySpec;
    use crate::sim::process::Process;
    use crate::sim::rng::Rng;
    use crate::sim::ServerlessSimulator;
    use std::sync::Arc;

    fn results_bits(r: &SimResults) -> Vec<u64> {
        vec![
            r.total_requests,
            r.cold_requests,
            r.warm_requests,
            r.rejected_requests,
            r.instances_created,
            r.instances_expired,
            r.cold_start_prob.to_bits(),
            r.avg_lifespan.to_bits(),
            r.avg_server_count.to_bits(),
            r.avg_running_count.to_bits(),
            r.avg_idle_count.to_bits(),
            r.max_server_count.to_bits(),
            r.avg_response_time.to_bits(),
            r.response_p50.to_bits(),
            r.response_p95.to_bits(),
            r.response_p99.to_bits(),
            r.billed_instance_seconds.to_bits(),
        ]
    }

    fn fleet_digest(res: &FleetResults) -> Vec<u64> {
        let mut d: Vec<u64> = res.per_function.iter().flat_map(results_bits).collect();
        let a = &res.aggregate;
        d.extend([
            a.total_requests,
            a.cold_requests,
            a.rejected_requests,
            a.cap_rejections,
            a.cold_start_prob.to_bits(),
            a.avg_server_count.to_bits(),
            a.response_p95.to_bits(),
            a.billed_instance_seconds.to_bits(),
        ]);
        d
    }

    #[test]
    fn one_function_fixed_fleet_reproduces_serverless_simulator_bitwise() {
        // The ISSUE's headline regression: fleet(1 fn, FixedExpiration,
        // no cap) == ServerlessSimulator, bit for bit, same seed.
        let cfg = SimConfig::table1().with_horizon(50_000.0).with_seed(0xFACE);
        let solo = ServerlessSimulator::new(cfg.clone()).run();
        let fleet = FleetConfig::from_sim_configs(
            &[cfg],
            PolicySpec::fixed(600.0),
        )
        .run();
        assert_eq!(fleet.per_function.len(), 1);
        assert_eq!(results_bits(&fleet.per_function[0]), results_bits(&solo));
        assert_eq!(fleet.per_function[0].instance_count_pmf, solo.instance_count_pmf);
        // The 1-function aggregate is that function.
        assert_eq!(fleet.aggregate.total_requests, solo.total_requests);
        assert_eq!(
            fleet.aggregate.avg_server_count.to_bits(),
            solo.avg_server_count.to_bits()
        );
    }

    #[test]
    fn one_function_batch_and_stochastic_expiration_still_match() {
        // The batch path and the stochastic-threshold path consume extra
        // RNG draws; the engine must mirror both.
        let mut cfg = SimConfig::table1().with_horizon(20_000.0).with_seed(7);
        cfg.batch_size = Some(Process::constant(2.0));
        cfg.expiration_process = Some(Process::exp_mean(600.0));
        let solo = ServerlessSimulator::new(cfg.clone()).run();
        let policy = PolicySpec::stochastic(Process::exp_mean(600.0));
        let fleet = FleetConfig::from_sim_configs(&[cfg], policy).run();
        assert_eq!(results_bits(&fleet.per_function[0]), results_bits(&solo));
    }

    #[test]
    fn sharded_fleet_bit_identical_across_thread_counts() {
        let mut rng = Rng::new(21);
        let trace = SyntheticTrace::generate(24, &mut rng);
        let base = FleetConfig::from_trace(&trace, 4_000.0, 0.0, 0xF1EE7, PolicySpec::fixed(300.0));
        let reference = base.clone().with_threads(1).run();
        for threads in [2, 8] {
            let res = base.clone().with_threads(threads).run();
            assert_eq!(fleet_digest(&res), fleet_digest(&reference), "threads={threads}");
        }
    }

    #[test]
    fn capped_domains_bit_identical_across_thread_counts() {
        // The ISSUE's capacity-domain determinism contract: each domain
        // is single-threaded and seq-tie-broken, so a K-domain capped run
        // must be bit-identical for any thread count.
        let mut rng = Rng::new(31);
        let trace = SyntheticTrace::generate(16, &mut rng);
        let base = FleetConfig::from_trace(&trace, 4_000.0, 0.0, 0xD0A1, PolicySpec::fixed(300.0))
            .with_fleet_cap(12)
            .with_capacity_domains(4);
        let reference = base.clone().with_threads(1).run();
        assert!(reference.aggregate.total_requests > 0);
        for threads in [2, 8] {
            let res = base.clone().with_threads(threads).run();
            assert_eq!(fleet_digest(&res), fleet_digest(&reference), "threads={threads}");
        }
    }

    #[test]
    fn capped_domains_match_sharded_when_cap_never_binds() {
        // With a cap so large no domain's share ever binds, admission
        // never couples anything and every function evolves exactly as in
        // the uncapped sharded path — for any K.
        let mut rng = Rng::new(32);
        let trace = SyntheticTrace::generate(8, &mut rng);
        let base = FleetConfig::from_trace(&trace, 3_000.0, 0.0, 5, PolicySpec::fixed(120.0));
        let sharded = base.clone().run();
        for k in [2, 4, 8] {
            let domains = base.clone().with_fleet_cap(1_000_000).with_capacity_domains(k).run();
            assert_eq!(fleet_digest(&sharded), fleet_digest(&domains), "k={k}");
            assert_eq!(domains.aggregate.cap_rejections, 0);
        }
    }

    #[test]
    fn domain_count_clamps_to_functions_and_capacity() {
        // K beyond the function count or the cap silently clamps (every
        // domain must own at least one function and one capacity unit);
        // the clamped-to-1 case routes through the legacy coupled path.
        let mut rng = Rng::new(33);
        let trace = SyntheticTrace::generate(3, &mut rng);
        let base = FleetConfig::from_trace(&trace, 2_000.0, 0.0, 7, PolicySpec::fixed(120.0))
            .with_fleet_cap(2);
        let legacy = base.clone().run();
        // cap=2 clamps any K to at most 2 domains; K=64 → 2.
        let clamped = base.clone().with_capacity_domains(64).run();
        let two = base.clone().with_capacity_domains(2).run();
        assert_eq!(fleet_digest(&clamped), fleet_digest(&two));
        // K=1 explicitly is the legacy path.
        let one = base.with_capacity_domains(1).run();
        assert_eq!(fleet_digest(&one), fleet_digest(&legacy));
    }

    #[test]
    fn clustered_domains_partition_hosts_and_stay_deterministic() {
        use crate::cluster::ClusterConfig;
        let mut rng = Rng::new(34);
        let trace = SyntheticTrace::generate(12, &mut rng);
        let base = FleetConfig::from_trace(&trace, 3_000.0, 0.0, 9, PolicySpec::fixed(120.0))
            .with_cluster(ClusterConfig::new(8, 4096.0, 32.0))
            .with_capacity_domains(4);
        let reference = base.clone().with_threads(1).run();
        // Contiguous 2-host blocks concatenate back to all 8 hosts.
        assert_eq!(reference.aggregate.host_utilization.len(), 8);
        assert!(reference.aggregate.total_requests > 0);
        for threads in [2, 8] {
            let res = base.clone().with_threads(threads).run();
            assert_eq!(fleet_digest(&res), fleet_digest(&reference), "threads={threads}");
            assert_eq!(
                res.aggregate.host_utilization,
                reference.aggregate.host_utilization,
                "threads={threads}"
            );
        }
    }

    #[test]
    fn coupled_matches_sharded_when_cap_never_binds() {
        let mut rng = Rng::new(22);
        let trace = SyntheticTrace::generate(8, &mut rng);
        let base = FleetConfig::from_trace(&trace, 3_000.0, 0.0, 5, PolicySpec::fixed(120.0));
        let sharded = base.clone().run();
        let coupled = base.clone().with_fleet_cap(1_000_000).run();
        assert_eq!(fleet_digest(&sharded), fleet_digest(&coupled));
        assert_eq!(coupled.aggregate.cap_rejections, 0);
    }

    #[test]
    fn fleet_cap_couples_functions_through_admission() {
        // Two hot functions that each need ~5 concurrent instances; a
        // fleet cap of 4 must starve them *jointly*.
        let mk = |seed: u64| {
            let mut c = SimConfig::table1().with_arrival_rate(2.5).with_horizon(20_000.0);
            c.seed = seed;
            c
        };
        let base = FleetConfig::from_sim_configs(&[mk(1), mk(2)], PolicySpec::fixed(600.0));
        let uncapped = base.clone().run();
        assert_eq!(uncapped.aggregate.rejected_requests, 0);
        let capped = base.with_fleet_cap(4).run();
        assert!(capped.aggregate.rejected_requests > 0);
        assert_eq!(
            capped.aggregate.cap_rejections,
            capped.aggregate.rejected_requests,
            "per-function limit (1000) never binds here; every rejection is the cap's"
        );
        // Both functions feel the cap (coupling, not per-function limits).
        assert!(capped.per_function.iter().all(|r| r.rejected_requests > 0));
        // The shared pool can never exceed the cap.
        assert!(capped.aggregate.avg_server_count <= 4.0 + 1e-9);
    }

    #[test]
    fn adaptive_policy_beats_fixed_thresholds_on_periodic_load() {
        // Cron-style function: one request every 100 s from t=100 to
        // t=10_000, then silence until the 50_000 s horizon. Deterministic
        // services make every number below exact.
        let periodic = || {
            let times: Vec<f64> = (1..=100).map(|i| i as f64 * 100.0).collect();
            FunctionSpec {
                name: "cron".into(),
                arrival: ArrivalMode::Trace(Arc::new(times)),
                batch_size: None,
                warm_service: Process::constant(1.0),
                cold_service: Process::constant(2.0),
                max_concurrency: 1000,
                memory_mb: 128.0,
                seed: 11,
            }
        };
        let run_with = |policy: PolicySpec| {
            FleetConfig {
                functions: vec![periodic()],
                policy,
                fleet_max_concurrency: None,
                cluster: None,
                capacity_domains: 1,
                horizon: 50_000.0,
                skip_initial: 0.0,
                threads: 1,
                prewarm_lead: 0.0,
                fault: FaultProfile::disabled(),
                retry: RetryPolicy::none(),
                telemetry: None,
                controller: None,
            }
            .run()
        };
        // A 60 s threshold is shorter than the 99 s idle gap: every
        // request cold-starts.
        let short = run_with(PolicySpec::fixed(60.0));
        assert!(short.aggregate.cold_start_prob > 0.99);
        // The histogram policy learns the period (tail bin 100 s -> window
        // 121 s) and keeps the instance warm: only the first request is
        // cold...
        let adaptive = run_with(PolicySpec::hybrid_histogram(600.0, 10.0));
        assert!(
            adaptive.aggregate.cold_start_prob < 0.02,
            "p_cold={}",
            adaptive.aggregate.cold_start_prob
        );
        // ...while holding the instance ~480 fewer idle server-seconds
        // after the workload goes quiet than a 600 s fixed threshold that
        // achieves the same cold-start rate (expiry ~t=10_122 vs ~10_601).
        let long = run_with(PolicySpec::fixed(600.0));
        assert_eq!(long.aggregate.cold_requests, adaptive.aggregate.cold_requests);
        let saved = (long.aggregate.avg_server_count - adaptive.aggregate.avg_server_count)
            * 50_000.0;
        assert!(
            (saved - 479.0).abs() < 25.0,
            "saved server-seconds = {saved} (long={}, adaptive={})",
            long.aggregate.avg_server_count,
            adaptive.aggregate.avg_server_count
        );
    }

    fn trace_fn(name: &str, times: Vec<f64>, seed: u64) -> FunctionSpec {
        FunctionSpec {
            name: name.into(),
            arrival: ArrivalMode::Trace(Arc::new(times)),
            batch_size: None,
            warm_service: Process::constant(5.0),
            cold_service: Process::constant(5.0),
            max_concurrency: 10,
            memory_mb: 128.0,
            seed,
        }
    }

    fn trace_fleet(functions: Vec<FunctionSpec>, horizon: f64) -> FleetConfig {
        FleetConfig {
            functions,
            policy: PolicySpec::fixed(600.0),
            fleet_max_concurrency: None,
            cluster: None,
            capacity_domains: 1,
            horizon,
            skip_initial: 0.0,
            threads: 1,
            prewarm_lead: 0.0,
            fault: FaultProfile::disabled(),
            retry: RetryPolicy::none(),
            telemetry: None,
            controller: None,
        }
    }

    #[test]
    fn cluster_capacity_emerges_from_host_memory() {
        use crate::cluster::ClusterConfig;
        // Two overlapping requests need two 128 MB containers; a single
        // 128 MB host can place only one, so the second arrival is
        // rejected by placement (the container serving the first is
        // busy, so there is nothing idle to evict). By t=30 the first
        // container is idle again and serves the third arrival warm.
        let base = trace_fleet(vec![trace_fn("t", vec![10.0, 10.5, 30.0], 3)], 100.0);
        let uncapped = base.clone().run();
        assert_eq!(uncapped.aggregate.rejected_requests, 0);
        assert!(uncapped.aggregate.host_utilization.is_empty());

        let clustered = base.with_cluster(ClusterConfig::new(1, 128.0, 32.0)).run();
        let a = &clustered.aggregate;
        assert_eq!(a.total_requests, 3);
        assert_eq!(a.cold_requests, 1);
        assert_eq!(a.warm_requests, 1);
        assert_eq!(a.rejected_requests, 1);
        assert_eq!(a.cap_rejections, 1, "the rejection is the cluster's");
        assert!(a.placement_failures >= 1);
        assert_eq!(a.evictions, 0, "a busy container is never evicted");
        assert_eq!(a.host_utilization.len(), 1);
        assert!(a.host_utilization[0] > 0.0);
        let table = a.to_table();
        assert!(table.contains("Cluster placement failures"));
    }

    #[test]
    fn pressure_eviction_reclaims_idle_containers() {
        use crate::cluster::ClusterConfig;
        // Function a's container idles after t=15; b's arrival at t=20
        // finds the single host full. With eviction on, the idle
        // container is reclaimed and b cold-starts; with eviction off,
        // b is rejected.
        let functions =
            || vec![trace_fn("a", vec![10.0], 1), trace_fn("b", vec![20.0], 2)];
        let evicting = trace_fleet(functions(), 100.0)
            .with_cluster(ClusterConfig::new(1, 128.0, 32.0))
            .run();
        assert_eq!(evicting.aggregate.rejected_requests, 0);
        assert_eq!(evicting.aggregate.evictions, 1);
        assert_eq!(evicting.aggregate.cold_requests, 2);

        let frozen = trace_fleet(functions(), 100.0)
            .with_cluster(ClusterConfig::new(1, 128.0, 32.0).with_eviction(false))
            .run();
        assert_eq!(frozen.aggregate.rejected_requests, 1);
        assert_eq!(frozen.aggregate.evictions, 0);
    }

    #[test]
    fn host_drain_evicts_idle_and_blocks_placement() {
        use crate::cluster::ClusterConfig;
        // A drain window [20, 40) on the only host: the idle container
        // left by the t=10 request is evicted when the window opens, the
        // t=25 arrival has nowhere to go, and the t=50 arrival placed
        // normally after the window closes.
        let cluster = ClusterConfig::new(1, 1024.0, 32.0).with_drain(0, 20.0, 40.0);
        let res = trace_fleet(vec![trace_fn("t", vec![10.0, 25.0, 50.0], 3)], 100.0)
            .with_cluster(cluster)
            .run();
        let a = &res.aggregate;
        assert_eq!(a.evictions, 1, "idle container evicted at window open");
        assert_eq!(a.rejected_requests, 1, "t=25 lands in the window");
        assert_eq!(a.cold_requests, 2, "t=10 and t=50 both cold-start");
        assert_eq!(a.warm_requests, 0);
    }

    #[test]
    fn unbounded_cluster_matches_uncapped_fleet() {
        use crate::cluster::ClusterConfig;
        // Placement that always succeeds must not perturb the engines:
        // the clustered runner reproduces the sharded fleet bit-for-bit
        // (the cluster draws no RNG and schedules no events).
        let mut rng = Rng::new(22);
        let trace = SyntheticTrace::generate(8, &mut rng);
        let base = FleetConfig::from_trace(&trace, 3_000.0, 0.0, 5, PolicySpec::fixed(120.0));
        let sharded = base.clone().run();
        let clustered = base.with_cluster(ClusterConfig::unbounded(1)).run();
        assert_eq!(fleet_digest(&sharded), fleet_digest(&clustered));
        assert_eq!(clustered.aggregate.cap_rejections, 0);
        assert_eq!(clustered.aggregate.placement_failures, 0);
        assert_eq!(clustered.aggregate.evictions, 0);
        assert_eq!(clustered.aggregate.host_utilization, vec![0.0]);
    }

    #[test]
    fn aggregate_sums_and_probabilities_are_consistent() {
        let mut rng = Rng::new(23);
        let trace = SyntheticTrace::generate(12, &mut rng);
        let res = FleetConfig::from_trace(&trace, 3_000.0, 0.0, 9, PolicySpec::fixed(600.0)).run();
        let a = &res.aggregate;
        let sum_total: u64 = res.per_function.iter().map(|r| r.total_requests).sum();
        assert_eq!(a.total_requests, sum_total);
        assert_eq!(a.total_requests, a.cold_requests + a.warm_requests + a.rejected_requests);
        let sum_server: f64 = res.per_function.iter().map(|r| r.avg_server_count).sum();
        assert!((a.avg_server_count - sum_server).abs() < 1e-12);
        assert!((a.avg_server_count - a.avg_running_count - a.avg_idle_count).abs() < 1e-9);
        assert!(a.cold_start_prob > 0.0 && a.cold_start_prob <= 1.0);
        let table = a.to_table();
        assert!(table.contains("Cold Start Probability"));
        assert!(table.contains("Functions"));
    }

    #[test]
    fn fleet_cost_totals_sum_per_function() {
        let mk = |seed: u64, rate: f64| {
            SimConfig::table1().with_arrival_rate(rate).with_horizon(10_000.0).with_seed(seed)
        };
        let cfg =
            FleetConfig::from_sim_configs(&[mk(1, 0.5), mk(2, 1.5)], PolicySpec::fixed(600.0));
        let res = cfg.run();
        let report = fleet_cost(&cfg, &res, &PricingTable::aws_lambda());
        assert_eq!(report.per_function.len(), 2);
        let dev_sum: f64 = report.per_function.iter().map(|e| e.developer_total()).sum();
        assert!((report.total.developer_total() - dev_sum).abs() < 1e-12);
        let infra_sum: f64 = report.per_function.iter().map(|e| e.provider_infra_cost).sum();
        assert!((report.total.provider_infra_cost - infra_sum).abs() < 1e-12);
        assert!(report.total.requests > 0.0);
    }

    #[test]
    fn trace_driven_arrivals_replay_every_timestamp() {
        // A hand-built trace: 10 arrivals, all before the horizon.
        let times: Vec<f64> = (0..10).map(|i| 10.0 + i as f64).collect();
        let spec = FunctionSpec {
            name: "t".into(),
            arrival: ArrivalMode::Trace(Arc::new(times)),
            batch_size: None,
            warm_service: Process::constant(0.5),
            cold_service: Process::constant(1.0),
            max_concurrency: 10,
            memory_mb: 128.0,
            seed: 3,
        };
        let cfg = FleetConfig {
            functions: vec![spec],
            policy: PolicySpec::fixed(600.0),
            fleet_max_concurrency: None,
            cluster: None,
            capacity_domains: 1,
            horizon: 100.0,
            skip_initial: 0.0,
            threads: 1,
            prewarm_lead: 0.0,
            fault: FaultProfile::disabled(),
            retry: RetryPolicy::none(),
            telemetry: None,
            controller: None,
        };
        let res = cfg.run();
        assert_eq!(res.aggregate.total_requests, 10);
        assert_eq!(res.aggregate.cold_requests, 1);
        assert_eq!(res.aggregate.warm_requests, 9);
        assert_eq!(res.aggregate.prewarm_starts, 0);
        assert_eq!(res.aggregate.wasted_prewarm_seconds, 0.0);
    }

    #[test]
    fn prewarm_reclaims_idle_tail_on_periodic_load() {
        // The cron workload from adaptive_policy_beats_fixed_thresholds,
        // now with the provisioning-lead prewarm arm: after the histogram
        // is confident the instance unloads right after each request and a
        // fresh one provisions ahead of the predicted next arrival, so the
        // cold-start count stays at 1 while the idle footprint collapses.
        let times: Vec<f64> = (1..=100).map(|i| i as f64 * 100.0).collect();
        let periodic = FunctionSpec {
            name: "cron".into(),
            arrival: ArrivalMode::Trace(Arc::new(times)),
            batch_size: None,
            warm_service: Process::constant(1.0),
            cold_service: Process::constant(2.0),
            max_concurrency: 1000,
            memory_mb: 128.0,
            seed: 11,
        };
        let base = FleetConfig {
            functions: vec![periodic],
            policy: PolicySpec::hybrid_histogram(600.0, 10.0),
            fleet_max_concurrency: None,
            cluster: None,
            capacity_domains: 1,
            horizon: 50_000.0,
            skip_initial: 0.0,
            threads: 1,
            prewarm_lead: 15.0,
            fault: FaultProfile::disabled(),
            retry: RetryPolicy::none(),
            telemetry: None,
            controller: None,
        };
        let plain = base.clone().with_prewarm_lead(0.0).run();
        let prewarmed = base.run();
        // Neither pays recurring cold starts...
        assert_eq!(plain.aggregate.cold_requests, 1);
        assert_eq!(prewarmed.aggregate.cold_requests, 1);
        assert_eq!(prewarmed.aggregate.total_requests, 100);
        // ...but the prewarm arm actually ran,
        assert!(
            prewarmed.aggregate.prewarm_starts > 50,
            "prewarm_starts={}",
            prewarmed.aggregate.prewarm_starts
        );
        // holds far fewer server-seconds (instance alive ~[90,101] of each
        // 100 s period instead of continuously),
        assert!(
            prewarmed.aggregate.avg_server_count < plain.aggregate.avg_server_count * 0.5,
            "prewarmed {} vs plain {}",
            prewarmed.aggregate.avg_server_count,
            plain.aggregate.avg_server_count
        );
        // and only the final speculative instance is wasted.
        assert!(
            prewarmed.aggregate.wasted_prewarm_seconds > 0.0
                && prewarmed.aggregate.wasted_prewarm_seconds < 120.0,
            "waste={}",
            prewarmed.aggregate.wasted_prewarm_seconds
        );
    }
}
