//! `ParServerlessSimulator` — the paper's extensibility demonstration
//! (§3.1): serverless platforms whose instances admit **queuing / a
//! concurrency value > 1** (Google Cloud Run, Knative; paper Fig. 1) while
//! keeping the scale-per-request expiration behaviour.
//!
//! Each instance can hold up to `concurrency_value` requests at once. An
//! arrival is routed to the *newest* instance with spare capacity
//! (consistent with the paper's newest-first routing priority); if none has
//! capacity and the platform is below the maximum concurrency level, a new
//! instance cold-starts. Requests in excess of an instance's processor share
//! its capacity: with k requests in service the per-request rate is
//! unaffected up to `concurrency_value` (Cloud Run semantics — concurrent
//! slots, not processor sharing), which reduces to scale-per-request when
//! `concurrency_value == 1`.

use super::event::{Event, EventQueue};
use super::hist::CountDistribution;
use super::instance::InstanceId;
use super::metrics::{OnlineStats, P2Quantile, TimeWeighted};
use super::results::SimResults;
use super::rng::Rng;
use super::simulator::SimConfig;
use super::time::SimTime;
use std::collections::BTreeMap;

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum ParState {
    Busy,
    Idle,
    Terminated,
}

#[derive(Debug, Clone)]
struct ParInstance {
    state: ParState,
    in_flight: u32,
    generation: u64,
    created_at: SimTime,
    busy_accum: f64,
    /// Start of the current "has in-flight work" period.
    busy_since: SimTime,
    terminated_at: SimTime,
}

/// Scale-per-request simulator generalized with a per-instance concurrency
/// value (paper Fig. 1: one instance absorbs `c` concurrent requests).
pub struct ParServerlessSimulator {
    cfg: SimConfig,
    pub concurrency_value: u32,
    rng: Rng,
    events: EventQueue,
    now: SimTime,
    instances: Vec<ParInstance>,
    /// Instances with spare slots, keyed by id (newest = max).
    available: BTreeMap<InstanceId, u32>,
    live_count: usize,
    /// Total in-flight requests.
    in_flight: u64,
    /// Count of instances in the `Busy` state, maintained incrementally on
    /// the three state transitions (Idle→Busy, cold start, Busy→Idle)
    /// instead of re-scanning every instance ever created on each event —
    /// the seed's per-event O(all-instances) scan dominated high-load runs
    /// (§Perf: the par/high_load_rate50 bench).
    busy_instances: usize,

    stats_started: bool,
    stats_start: SimTime,
    total_requests: u64,
    cold_requests: u64,
    warm_requests: u64,
    rejected_requests: u64,
    instances_created: u64,
    instances_expired: u64,
    server_tw: TimeWeighted,
    running_tw: TimeWeighted,
    busy_inst_tw: TimeWeighted,
    count_dist: CountDistribution,
    lifespan_stats: OnlineStats,
    response_stats: OnlineStats,
    warm_response_stats: OnlineStats,
    cold_response_stats: OnlineStats,
    response_p50: P2Quantile,
    response_p95: P2Quantile,
    response_p99: P2Quantile,
    billed_seconds: f64,
}

impl ParServerlessSimulator {
    pub fn new(cfg: SimConfig, concurrency_value: u32) -> Self {
        assert!(concurrency_value >= 1);
        let rng = Rng::new(cfg.seed);
        let start = SimTime::ZERO;
        ParServerlessSimulator {
            concurrency_value,
            rng,
            events: EventQueue::with_capacity(4096),
            now: start,
            instances: Vec::with_capacity(1024),
            available: BTreeMap::new(),
            live_count: 0,
            in_flight: 0,
            busy_instances: 0,
            stats_started: cfg.skip_initial <= 0.0,
            stats_start: SimTime::from_secs(cfg.skip_initial.max(0.0)),
            total_requests: 0,
            cold_requests: 0,
            warm_requests: 0,
            rejected_requests: 0,
            instances_created: 0,
            instances_expired: 0,
            server_tw: TimeWeighted::new(start, 0.0),
            running_tw: TimeWeighted::new(start, 0.0),
            busy_inst_tw: TimeWeighted::new(start, 0.0),
            count_dist: CountDistribution::new(start, 0),
            lifespan_stats: OnlineStats::new(),
            response_stats: OnlineStats::new(),
            warm_response_stats: OnlineStats::new(),
            cold_response_stats: OnlineStats::new(),
            response_p50: P2Quantile::new(0.5),
            response_p95: P2Quantile::new(0.95),
            response_p99: P2Quantile::new(0.99),
            billed_seconds: 0.0,
            cfg,
        }
    }

    /// O(1): every level is an incrementally-maintained counter.
    fn sync(&mut self) {
        self.server_tw.update(self.now, self.live_count as f64);
        self.running_tw.update(self.now, self.in_flight as f64);
        self.busy_inst_tw.update(self.now, self.busy_instances as f64);
        self.count_dist.update(self.now, self.live_count);
    }

    fn record_response(&mut self, rt: f64, cold: bool) {
        if !self.stats_started {
            return;
        }
        self.response_stats.push(rt);
        if cold {
            self.cold_response_stats.push(rt);
        } else {
            self.warm_response_stats.push(rt);
        }
        self.response_p50.push(rt);
        self.response_p95.push(rt);
        self.response_p99.push(rt);
    }

    fn maybe_start_stats(&mut self, t: SimTime) {
        if self.stats_started || t < self.stats_start {
            return;
        }
        let b = self.stats_start;
        self.server_tw.advance(b);
        self.running_tw.advance(b);
        self.busy_inst_tw.advance(b);
        self.count_dist.finish(b);
        self.server_tw.reset_at(b);
        self.running_tw.reset_at(b);
        self.busy_inst_tw.reset_at(b);
        self.count_dist.reset_at(b);
        self.stats_started = true;
    }

    fn handle_arrival(&mut self) {
        if self.stats_started {
            self.total_requests += 1;
        }
        // Newest instance with spare capacity.
        let target = self.available.iter().next_back().map(|(&id, &slots)| (id, slots));
        if let Some((id, slots)) = target {
            let inst = &mut self.instances[id.0 as usize];
            if inst.state == ParState::Idle {
                inst.state = ParState::Busy;
                inst.busy_since = self.now;
                inst.generation += 1; // cancel pending expiration
                self.busy_instances += 1;
            }
            inst.in_flight += 1;
            self.in_flight += 1;
            if slots <= 1 {
                self.available.remove(&id);
            } else {
                self.available.insert(id, slots - 1);
            }
            let service = self.cfg.warm_service.sample(&mut self.rng);
            self.events.schedule(self.now.after(service), Event::Departure(id));
            if self.stats_started {
                self.warm_requests += 1;
            }
            self.record_response(service, false);
            self.sync();
        } else if self.live_count < self.cfg.max_concurrency {
            let id = InstanceId(self.instances.len() as u64);
            self.instances.push(ParInstance {
                state: ParState::Busy,
                in_flight: 1,
                generation: 0,
                created_at: self.now,
                busy_accum: 0.0,
                busy_since: self.now,
                terminated_at: self.now,
            });
            self.live_count += 1;
            self.in_flight += 1;
            self.busy_instances += 1;
            if self.concurrency_value > 1 {
                self.available.insert(id, self.concurrency_value - 1);
            }
            let service = self.cfg.cold_service.sample(&mut self.rng);
            self.events.schedule(self.now.after(service), Event::Departure(id));
            if self.stats_started {
                self.cold_requests += 1;
                self.instances_created += 1;
            }
            self.record_response(service, true);
            self.sync();
        } else {
            // Rejection changes no level: skip the accumulator sync.
            if self.stats_started {
                self.rejected_requests += 1;
            }
        }
        let gap = self.cfg.arrival.sample(&mut self.rng);
        self.events.schedule(self.now.after(gap), Event::Arrival);
    }

    fn handle_departure(&mut self, id: InstanceId) {
        let schedule_expiration;
        let gen;
        {
            let inst = &mut self.instances[id.0 as usize];
            debug_assert!(inst.in_flight > 0);
            inst.in_flight -= 1;
            self.in_flight -= 1;
            if inst.in_flight == 0 {
                // Busy period ends; bill it once (slots share the instance).
                let busy = self.now.since(inst.busy_since).max(0.0);
                inst.busy_accum += busy;
                if self.stats_started {
                    self.billed_seconds += busy;
                }
                inst.state = ParState::Idle;
                inst.generation += 1;
                schedule_expiration = true;
                gen = inst.generation;
                self.busy_instances -= 1;
            } else {
                schedule_expiration = false;
                gen = inst.generation;
            }
        }
        // Free one slot.
        let slots = self.available.get(&id).copied().unwrap_or(0) + 1;
        self.available.insert(id, slots.min(self.concurrency_value));
        if schedule_expiration {
            let threshold = self.cfg.expiration_threshold;
            self.events.schedule(self.now.after(threshold), Event::Expiration { id, gen });
        }
        self.sync();
    }

    fn handle_expiration(&mut self, id: InstanceId, gen: u64) {
        let inst = &mut self.instances[id.0 as usize];
        if inst.generation != gen || inst.state != ParState::Idle {
            return;
        }
        inst.state = ParState::Terminated;
        inst.terminated_at = self.now;
        let lifespan = self.now.since(inst.created_at);
        self.available.remove(&id);
        self.live_count -= 1;
        if self.stats_started {
            self.instances_expired += 1;
            self.lifespan_stats.push(lifespan);
        }
        self.sync();
    }

    pub fn run(&mut self) -> SimResults {
        let horizon = SimTime::from_secs(self.cfg.horizon);
        let first = self.cfg.arrival.sample(&mut self.rng);
        self.events.schedule(SimTime::from_secs(first), Event::Arrival);
        self.events.schedule(horizon, Event::Horizon);
        while let Some((t, ev)) = self.events.pop() {
            self.maybe_start_stats(t);
            self.now = t;
            match ev {
                Event::Arrival => self.handle_arrival(),
                Event::Departure(id) => self.handle_departure(id),
                Event::Expiration { id, gen } => self.handle_expiration(id, gen),
                Event::ProvisioningDone(_) => unreachable!(),
                Event::Horizon => break,
            }
        }
        self.now = horizon;
        self.server_tw.advance(horizon);
        self.running_tw.advance(horizon);
        self.busy_inst_tw.advance(horizon);
        self.count_dist.finish(horizon);

        let measured = horizon.since(self.stats_start).max(0.0);
        let served = self.cold_requests + self.warm_requests;
        let avg_server = self.server_tw.average();
        let avg_busy_inst = self.busy_inst_tw.average();
        SimResults {
            measured_time: measured,
            total_requests: self.total_requests,
            cold_requests: self.cold_requests,
            warm_requests: self.warm_requests,
            rejected_requests: self.rejected_requests,
            cold_start_prob: if served > 0 {
                self.cold_requests as f64 / served as f64
            } else {
                0.0
            },
            rejection_prob: if self.total_requests > 0 {
                self.rejected_requests as f64 / self.total_requests as f64
            } else {
                0.0
            },
            avg_lifespan: self.lifespan_stats.mean(),
            instances_created: self.instances_created,
            instances_expired: self.instances_expired,
            avg_server_count: avg_server,
            avg_running_count: self.running_tw.average(),
            avg_idle_count: avg_server - avg_busy_inst,
            max_server_count: self.server_tw.max_level(),
            wasted_capacity: if avg_server > 0.0 {
                (avg_server - avg_busy_inst) / avg_server
            } else {
                0.0
            },
            avg_response_time: self.response_stats.mean(),
            avg_warm_response_time: self.warm_response_stats.mean(),
            avg_cold_response_time: self.cold_response_stats.mean(),
            response_p50: self.response_p50.quantile(),
            response_p95: self.response_p95.quantile(),
            response_p99: self.response_p99.quantile(),
            billed_instance_seconds: self.billed_seconds,
            observed_arrival_rate: if measured > 0.0 {
                self.total_requests as f64 / measured
            } else {
                0.0
            },
            instance_count_pmf: self.count_dist.pmf(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::process::{ExpProcess, Process};
    use crate::sim::simulator::ServerlessSimulator;

    fn cfg(rate: f64, horizon: f64, seed: u64) -> SimConfig {
        SimConfig {
            arrival: Process::exp_rate(rate),
            batch_size: None,
            warm_service: Process::exp_mean(1.991),
            cold_service: Process::exp_mean(2.244),
            expiration_threshold: 600.0,
            expiration_process: None,
            max_concurrency: 1000,
            horizon,
            skip_initial: 100.0,
            seed,
            capture_request_log: false,
            sample_interval: 0.0,
        }
    }

    #[test]
    fn concurrency_one_matches_scale_per_request() {
        // With c=1 the generalized simulator must agree (statistically)
        // with ServerlessSimulator on the same workload.
        let r1 = ParServerlessSimulator::new(cfg(0.9, 100_000.0, 1), 1).run();
        let r2 = ServerlessSimulator::new(cfg(0.9, 100_000.0, 1)).run();
        assert!((r1.avg_server_count - r2.avg_server_count).abs() / r2.avg_server_count < 0.05);
        assert!((r1.avg_running_count - r2.avg_running_count).abs() / r2.avg_running_count < 0.05);
        // Cold start probabilities are both sub-1%.
        assert!(r1.cold_start_prob < 0.01 && r2.cold_start_prob < 0.01);
    }

    #[test]
    fn higher_concurrency_needs_fewer_instances() {
        // Paper Fig. 1: c=3 absorbs the same traffic with fewer instances.
        let r1 = ParServerlessSimulator::new(cfg(3.0, 100_000.0, 2), 1).run();
        let r3 = ParServerlessSimulator::new(cfg(3.0, 100_000.0, 2), 3).run();
        assert!(
            r3.avg_server_count < r1.avg_server_count,
            "c=3 {} vs c=1 {}",
            r3.avg_server_count,
            r1.avg_server_count
        );
        assert!(r3.cold_start_prob <= r1.cold_start_prob + 0.01);
    }

    #[test]
    fn in_flight_never_exceeds_capacity() {
        let mut sim = ParServerlessSimulator::new(cfg(5.0, 5_000.0, 3), 4);
        let _ = sim.run();
        for inst in &sim.instances {
            assert!(inst.in_flight <= 4);
        }
    }

    #[test]
    fn rejection_when_capacity_exhausted() {
        let mut c = cfg(50.0, 5_000.0, 4);
        c.max_concurrency = 3;
        let r = ParServerlessSimulator::new(c, 2).run();
        // Offered load 50*2 ~ 100 >> 6 slots.
        assert!(r.rejection_prob > 0.5);
    }

    #[test]
    fn busy_counter_matches_full_scan() {
        // The incrementally-maintained busy-instance counter must agree
        // with a from-scratch recount of every instance ever created (the
        // seed's per-event O(n) scan, now a test-only oracle).
        for seed in [5u64, 6, 7] {
            let mut sim = ParServerlessSimulator::new(cfg(8.0, 10_000.0, seed), 3);
            let _ = sim.run();
            let scan = sim
                .instances
                .iter()
                .filter(|i| i.state == ParState::Busy)
                .count();
            assert_eq!(sim.busy_instances, scan, "seed {seed}");
        }
    }

    #[test]
    fn enum_and_custom_dispatch_bit_identical() {
        // Regression vs the seed behavior: swapping the monomorphic enum
        // for the trait-object escape hatch (the seed's dispatch mechanism)
        // changes nothing on a fixed seed — counters, averages, and the
        // new percentile estimators all match bit-for-bit.
        let base = cfg(5.0, 50_000.0, 9);
        let mut custom = base.clone();
        custom.arrival = Process::custom(ExpProcess::with_rate(5.0));
        custom.warm_service = Process::custom(ExpProcess::with_mean(1.991));
        custom.cold_service = Process::custom(ExpProcess::with_mean(2.244));
        let a = ParServerlessSimulator::new(base, 2).run();
        let b = ParServerlessSimulator::new(custom, 2).run();
        assert_eq!(a.total_requests, b.total_requests);
        assert_eq!(a.cold_requests, b.cold_requests);
        assert_eq!(a.warm_requests, b.warm_requests);
        assert_eq!(a.instances_expired, b.instances_expired);
        assert_eq!(a.avg_server_count.to_bits(), b.avg_server_count.to_bits());
        assert_eq!(
            a.billed_instance_seconds.to_bits(),
            b.billed_instance_seconds.to_bits()
        );
        assert_eq!(a.response_p95.to_bits(), b.response_p95.to_bits());
    }

    #[test]
    fn percentiles_at_c1_match_scale_per_request_simulator() {
        // With c=1 and a deterministic expiration threshold the two
        // simulators are the same stochastic system drawing the same RNG
        // stream, so the P2 percentile estimators see identical response
        // sequences.
        let c = cfg(0.9, 100_000.0, 11);
        let par = ParServerlessSimulator::new(c.clone(), 1).run();
        let spr = ServerlessSimulator::new(c).run();
        assert_eq!(par.total_requests, spr.total_requests);
        assert_eq!(par.cold_requests, spr.cold_requests);
        assert!(par.response_p50.is_finite() && par.response_p50 > 0.0);
        assert!((par.response_p50 - spr.response_p50).abs() < 1e-9);
        assert!((par.response_p95 - spr.response_p95).abs() < 1e-9);
        assert!((par.response_p99 - spr.response_p99).abs() < 1e-9);
        // Percentiles are ordered and bracket the mean sanely.
        assert!(par.response_p50 <= par.response_p95);
        assert!(par.response_p95 <= par.response_p99);
    }
}
