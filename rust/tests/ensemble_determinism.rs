//! Integration test: the `sim::ensemble` determinism contract.
//!
//! The ensemble engine promises that a fixed `(config, root_seed,
//! replications)` triple produces **bit-identical** aggregated results for
//! any thread count — the property that makes parallel replication a pure
//! speedup rather than a reproducibility trade-off. These tests pin that
//! contract at 1, 2, and 8 threads, for the plain simulator, the
//! concurrency-value simulator, the stateful MMPP arrival process (which
//! requires per-replication process replicas), and the temporal simulator
//! that Fig. 4 is built on.

use simfaas::fleet::{FleetConfig, FleetResults, PolicySpec};
use simfaas::sim::ensemble::{run_ensemble, run_par_ensemble, EnsembleOpts};
use simfaas::sim::{
    EnsembleResults, InitialState, Process, Rng, ServerlessTemporalSimulator, SimConfig,
};
use simfaas::workload::SyntheticTrace;

/// Exact (bit-level) digest of an ensemble's aggregated output.
fn digest(res: &EnsembleResults) -> Vec<u64> {
    let mut d: Vec<u64> = res.seeds.clone();
    for r in &res.runs {
        d.push(r.total_requests);
        d.push(r.cold_requests);
        d.push(r.warm_requests);
        d.push(r.rejected_requests);
        d.push(r.avg_server_count.to_bits());
        d.push(r.avg_running_count.to_bits());
        d.push(r.billed_instance_seconds.to_bits());
        d.push(r.response_p99.to_bits());
    }
    let s = res.summary();
    d.push(s.cold_start_prob.mean.to_bits());
    d.push(s.cold_start_prob.ci_half.to_bits());
    d.push(s.avg_server_count.mean.to_bits());
    d.push(s.avg_server_count.ci_half.to_bits());
    d
}

#[test]
fn same_root_seed_bit_identical_across_1_2_8_threads() {
    let cfg = SimConfig::table1().with_horizon(10_000.0);
    let reference = run_ensemble(&cfg, &EnsembleOpts::new(8, 0xD15C).with_threads(1));
    for threads in [2, 8] {
        let res = run_ensemble(&cfg, &EnsembleOpts::new(8, 0xD15C).with_threads(threads));
        assert_eq!(digest(&res), digest(&reference), "threads={threads}");
    }
}

#[test]
fn different_root_seeds_differ() {
    let cfg = SimConfig::table1().with_horizon(5_000.0);
    let a = run_ensemble(&cfg, &EnsembleOpts::new(4, 1));
    let b = run_ensemble(&cfg, &EnsembleOpts::new(4, 2));
    assert_ne!(digest(&a), digest(&b));
}

#[test]
fn stateful_mmpp_arrival_is_still_deterministic() {
    // MMPP keeps mutable phase state; without per-replication replicas,
    // parallel replications would race on it and the digest would depend
    // on scheduling. replica_with_seed re-creates the process per
    // replication, restoring the contract.
    let mut cfg = SimConfig::table1().with_horizon(5_000.0);
    cfg.arrival = Process::mmpp([3.0, 0.3], [0.02, 0.02]);
    let reference = run_ensemble(&cfg, &EnsembleOpts::new(8, 0xABCD).with_threads(1));
    for threads in [2, 8] {
        let res = run_ensemble(&cfg, &EnsembleOpts::new(8, 0xABCD).with_threads(threads));
        assert_eq!(digest(&res), digest(&reference), "threads={threads}");
    }
}

#[test]
fn par_simulator_ensemble_deterministic() {
    let cfg = SimConfig::table1().with_arrival_rate(3.0).with_horizon(5_000.0);
    let reference = run_par_ensemble(&cfg, 3, &EnsembleOpts::new(6, 0xF00).with_threads(1));
    for threads in [2, 8] {
        let res = run_par_ensemble(&cfg, 3, &EnsembleOpts::new(6, 0xF00).with_threads(threads));
        assert_eq!(digest(&res), digest(&reference), "threads={threads}");
    }
}

/// Exact digest of a fleet run: every per-function result plus the rollup.
fn fleet_digest(res: &FleetResults) -> Vec<u64> {
    let mut d = Vec::new();
    for r in &res.per_function {
        d.push(r.total_requests);
        d.push(r.cold_requests);
        d.push(r.warm_requests);
        d.push(r.rejected_requests);
        d.push(r.avg_server_count.to_bits());
        d.push(r.avg_running_count.to_bits());
        d.push(r.billed_instance_seconds.to_bits());
        d.push(r.response_p99.to_bits());
    }
    let a = &res.aggregate;
    d.push(a.total_requests);
    d.push(a.cold_requests);
    d.push(a.cold_start_prob.to_bits());
    d.push(a.avg_server_count.to_bits());
    d.push(a.response_p95.to_bits());
    d.push(a.billed_instance_seconds.to_bits());
    d
}

#[test]
fn fleet_shards_bit_identical_across_1_2_8_threads() {
    // The fleet simulator shards functions over the same indexed runner as
    // the replication ensemble, so it inherits the identical contract:
    // per-function AND aggregate output must not depend on shard count.
    let mut rng = Rng::new(0xF17);
    let trace = SyntheticTrace::generate(32, &mut rng);
    let base = FleetConfig::from_trace(
        &trace,
        5_000.0,
        0.0,
        0xF17,
        PolicySpec::hybrid_histogram(3_600.0, 60.0),
    );
    let reference = base.clone().with_threads(1).run();
    for threads in [2, 8] {
        let res = base.clone().with_threads(threads).run();
        assert_eq!(fleet_digest(&res), fleet_digest(&reference), "threads={threads}");
    }
}

#[test]
fn fleet_different_root_seeds_differ() {
    let mut rng = Rng::new(0xF18);
    let trace = SyntheticTrace::generate(8, &mut rng);
    let a = FleetConfig::from_trace(&trace, 3_000.0, 0.0, 1, PolicySpec::fixed(600.0)).run();
    let b = FleetConfig::from_trace(&trace, 3_000.0, 0.0, 2, PolicySpec::fixed(600.0)).run();
    assert_ne!(fleet_digest(&a), fleet_digest(&b));
}

#[test]
fn temporal_simulator_rides_the_same_contract() {
    let mut cfg = SimConfig::table1().with_horizon(3_000.0);
    cfg.skip_initial = 0.0;
    cfg.sample_interval = 100.0;
    let sim = ServerlessTemporalSimulator::new(cfg, InitialState::warm_pool(5), 8);
    let seq = sim.run_with_threads(1);
    let par = sim.run_with_threads(8);
    assert_eq!(seq.runs.len(), par.runs.len());
    for (a, b) in seq.runs.iter().zip(&par.runs) {
        assert_eq!(a.total_requests, b.total_requests);
        assert_eq!(a.avg_server_count.to_bits(), b.avg_server_count.to_bits());
    }
    let band_a = seq.average_count_band();
    let band_b = par.average_count_band();
    assert_eq!(band_a.len(), band_b.len());
    for ((t1, m1, h1), (t2, m2, h2)) in band_a.iter().zip(&band_b) {
        assert_eq!(t1.to_bits(), t2.to_bits());
        assert_eq!(m1.to_bits(), m2.to_bits());
        assert_eq!(h1.to_bits(), h2.to_bits());
    }
}
