//! Reader for the public **Azure Functions 2019 dataset** (Shahrad et al.,
//! "Serverless in the Wild", ATC'20) — the real-trace half of the dual
//! synthetic/ingested workload path (DESIGN.md §3).
//!
//! The dataset ships per day as three CSVs, located in one directory by
//! filename prefix (the published `.anon.d01.csv` suffixes — or any `.csv`
//! suffix — are accepted):
//!
//! * `invocations_per_function*.csv` — `HashOwner,HashApp,HashFunction,
//!   Trigger,1,2,…,1440`: invocation counts per minute of the day.
//! * `function_durations_percentiles*.csv` — per-function execution-time
//!   statistics in milliseconds (`Average` plus `percentile_Average_*`
//!   columns).
//! * `app_memory_percentiles*.csv` — per-app allocated memory
//!   (`AverageAllocatedMb`).
//!
//! [`AzureDataset::load`] joins the three files into
//! [`IngestedFunction`]s: a per-minute rate profile (replayed lazily by
//! [`super::stream::StreamingArrivals`] — nothing is materialized), fitted
//! warm/cold service means, and the app's memory allocation. Every parse
//! or consistency failure is reported with the offending **file and line
//! number**. A small transform layer ([`top_k`](AzureDataset::top_k),
//! [`slice`](AzureDataset::slice),
//! [`scale_rates`](AzureDataset::scale_rates)) narrows or rescales the mix
//! before simulation, and each applied transform is recorded for
//! provenance reporting.
//!
//! **Service-time fit.** The dataset does not split cold from warm
//! executions, so the fit is a documented modeling choice: the warm mean is
//! the function's `Average` duration (ms → s, floored at 1 ms), and the
//! cold mean adds the `p99 − p50` duration spread (the tail of production
//! durations absorbs cold invocations) floored at
//! [`COLD_OVERHEAD_FLOOR`] — matching the paper's observation that cold
//! responses dominate the tail. Compare an ingested mix against the
//! synthetic generator with [`super::source::TraceSource::rate_stats`].

use anyhow::{bail, Context, Result};
use std::collections::BTreeMap;
use std::path::{Path, PathBuf};
use std::sync::Arc;

/// Minutes per day — the column count of a full invocations row. Narrower
/// files (useful in tests) are accepted; the rate profile's period is
/// simply `columns * 60` seconds.
pub const MINUTES_PER_DAY: usize = 1440;

/// Minimum cold-start overhead (s) added to the fitted warm mean when the
/// duration percentiles are too tight to expose a tail (see module docs).
pub const COLD_OVERHEAD_FLOOR: f64 = 0.25;

/// Memory (MB) assumed for functions whose app has no row in the memory
/// file. The published dataset samples memory for a *subset* of apps, so
/// a missing app row is expected on real data (unlike a missing durations
/// row, which is a genuine identity inconsistency and errors).
pub const DEFAULT_MEMORY_MB: f64 = 128.0;

/// One function ingested from the dataset: identity, per-minute rate
/// profile, fitted service means, and its app's memory allocation.
#[derive(Debug, Clone)]
pub struct IngestedFunction {
    /// Short display name (leading 8 chars of the function hash).
    pub name: String,
    /// Invocation rate per minute-of-day bin, in req/s.
    pub minute_rates: Arc<Vec<f64>>,
    /// Total invocations over the traced day (sum of the minute counts).
    pub total_invocations: u64,
    /// Fitted warm service mean (s).
    pub warm_service_mean: f64,
    /// Fitted cold service mean (s); always above the warm mean.
    pub cold_service_mean: f64,
    /// Allocated memory (MB) inherited from the function's app row.
    pub memory_mb: f64,
}

impl IngestedFunction {
    /// Mean rate (req/s) averaged over the traced day.
    pub fn mean_rate(&self) -> f64 {
        if self.minute_rates.is_empty() {
            0.0
        } else {
            self.minute_rates.iter().sum::<f64>() / self.minute_rates.len() as f64
        }
    }
}

/// An ingested Azure Functions 2019 trace: the joined function list plus
/// provenance (source directory, pre-transform size, applied transforms).
#[derive(Debug, Clone)]
pub struct AzureDataset {
    /// The ingested functions, in dataset file order (until transformed).
    pub functions: Vec<IngestedFunction>,
    /// The directory the three CSVs were read from.
    pub source_dir: String,
    /// Function count before any transform was applied.
    pub raw_functions: usize,
    /// Human-readable transform chain (`top_k(20)`, `scale_rates(2)`, …).
    pub transforms: Vec<String>,
}

/// Column indices resolved from a CSV header by name.
fn header_indices<'a>(
    header: &'a str,
    required: &[&str],
    file: &str,
) -> Result<BTreeMap<&'a str, usize>> {
    let cols: Vec<&str> = header.split(',').map(str::trim).collect();
    let mut map = BTreeMap::new();
    for (i, c) in cols.iter().enumerate() {
        map.insert(*c, i);
    }
    for name in required {
        if !map.contains_key(name) {
            bail!(
                "{file}:1: missing required column {name:?} (header has: {})",
                cols.join(", ")
            );
        }
    }
    Ok(map)
}

fn parse_field(cols: &[&str], idx: usize, file: &str, line: usize, what: &str) -> Result<f64> {
    let raw = cols.get(idx).copied().unwrap_or("");
    raw.trim()
        .parse::<f64>()
        .ok()
        .filter(|v| v.is_finite())
        .with_context(|| format!("{file}:{line}: {what} {raw:?} is not a finite number"))
}

/// Locate the single `prefix*.csv` file in `dir`.
fn find_csv(dir: &Path, prefix: &str) -> Result<PathBuf> {
    let entries = std::fs::read_dir(dir)
        .with_context(|| format!("reading trace directory {}", dir.display()))?;
    let mut hits: Vec<PathBuf> = Vec::new();
    for entry in entries {
        let entry = entry.with_context(|| format!("reading trace directory {}", dir.display()))?;
        let name = entry.file_name().to_string_lossy().into_owned();
        if name.starts_with(prefix) && name.ends_with(".csv") {
            hits.push(entry.path());
        }
    }
    hits.sort();
    match hits.len() {
        0 => bail!(
            "{}: no {prefix}*.csv file found (expected the Azure Functions 2019 dataset \
             layout: invocations_per_function*.csv, function_durations_percentiles*.csv, \
             app_memory_percentiles*.csv)",
            dir.display()
        ),
        1 => Ok(hits.remove(0)),
        _ => bail!(
            "{}: multiple {prefix}*.csv files found ({}); keep exactly one per kind",
            dir.display(),
            hits.iter().map(|p| p.display().to_string()).collect::<Vec<_>>().join(", ")
        ),
    }
}

/// (owner, app, function) identity key.
type FnKey = (String, String, String);

struct InvRow {
    key: FnKey,
    line: usize,
    counts: Vec<f64>,
}

struct DurRow {
    avg_ms: f64,
    p50_ms: f64,
    p99_ms: f64,
}

fn non_empty_lines(text: &str) -> impl Iterator<Item = (usize, &str)> {
    text.lines().enumerate().filter_map(|(i, l)| {
        let t = l.trim();
        if t.is_empty() {
            None
        } else {
            Some((i + 1, t))
        }
    })
}

/// Streaming parse of the invocations file — the big one (hundreds of MB
/// for a real published day), read line by line so peak memory stays at
/// the parsed rows, not the whole file text.
fn parse_invocations(path: &Path) -> Result<Vec<InvRow>> {
    use std::io::BufRead;
    let file = path.display().to_string();
    let handle = std::fs::File::open(path).with_context(|| format!("reading {file}"))?;
    let reader = std::io::BufReader::new(handle);
    let mut width = 0usize;
    let mut rows: Vec<InvRow> = Vec::new();
    let mut seen: BTreeMap<FnKey, usize> = BTreeMap::new();
    for (i, line) in reader.lines().enumerate() {
        let line = line.with_context(|| format!("reading {file}"))?;
        let text_line = line.trim();
        if text_line.is_empty() {
            continue;
        }
        let line_no = i + 1;
        let cols: Vec<&str> = text_line.split(',').map(str::trim).collect();
        if width == 0 {
            // First non-empty line is the header.
            if cols.len() < 5
                || cols[0] != "HashOwner"
                || cols[1] != "HashApp"
                || cols[2] != "HashFunction"
                || cols[3] != "Trigger"
            {
                bail!(
                    "{file}:{line_no}: header must start with \
                     HashOwner,HashApp,HashFunction,Trigger followed by at least one \
                     per-minute count column, got {text_line:?}"
                );
            }
            width = cols.len();
            continue;
        }
        if cols.len() != width {
            bail!("{file}:{line_no}: expected {width} columns, got {}", cols.len());
        }
        let key: FnKey = (cols[0].to_string(), cols[1].to_string(), cols[2].to_string());
        if let Some(prev) = seen.insert(key.clone(), line_no) {
            bail!(
                "{file}:{line_no}: duplicate function {} (first seen at line {prev})",
                cols[2]
            );
        }
        let mut counts = Vec::with_capacity(width - 4);
        for (j, raw) in cols[4..].iter().enumerate() {
            let v = parse_field(&cols, 4 + j, &file, line_no, "invocation count")?;
            if v < 0.0 {
                bail!("{file}:{line_no}: invocation count {raw:?} is negative");
            }
            counts.push(v);
        }
        rows.push(InvRow { key, line: line_no, counts });
    }
    if width == 0 {
        bail!("{file}: file is empty");
    }
    if rows.is_empty() {
        bail!("{file}: contains a header but no data rows");
    }
    Ok(rows)
}

fn parse_durations(path: &Path) -> Result<BTreeMap<FnKey, DurRow>> {
    let file = path.display().to_string();
    let text = std::fs::read_to_string(path).with_context(|| format!("reading {file}"))?;
    let mut lines = non_empty_lines(&text);
    let (_, header) = lines.next().with_context(|| format!("{file}: file is empty"))?;
    let idx = header_indices(
        header,
        &[
            "HashOwner",
            "HashApp",
            "HashFunction",
            "Average",
            "percentile_Average_50",
            "percentile_Average_99",
        ],
        &file,
    )?;
    let width = header.split(',').count();
    let mut out: BTreeMap<FnKey, DurRow> = BTreeMap::new();
    let mut seen: BTreeMap<FnKey, usize> = BTreeMap::new();
    for (line, text_line) in lines {
        let cols: Vec<&str> = text_line.split(',').map(str::trim).collect();
        if cols.len() != width {
            bail!("{file}:{line}: expected {width} columns, got {}", cols.len());
        }
        let key: FnKey = (
            cols[idx["HashOwner"]].to_string(),
            cols[idx["HashApp"]].to_string(),
            cols[idx["HashFunction"]].to_string(),
        );
        if let Some(prev) = seen.insert(key.clone(), line) {
            bail!(
                "{file}:{line}: duplicate function {} (first seen at line {prev})",
                cols[idx["HashFunction"]]
            );
        }
        let avg_ms = parse_field(&cols, idx["Average"], &file, line, "Average duration")?;
        let p50_ms =
            parse_field(&cols, idx["percentile_Average_50"], &file, line, "p50 duration")?;
        let p99_ms =
            parse_field(&cols, idx["percentile_Average_99"], &file, line, "p99 duration")?;
        if avg_ms < 0.0 || p50_ms < 0.0 || p99_ms < 0.0 {
            bail!("{file}:{line}: durations must be non-negative milliseconds");
        }
        out.insert(key, DurRow { avg_ms, p50_ms, p99_ms });
    }
    if out.is_empty() {
        bail!("{file}: contains a header but no data rows");
    }
    Ok(out)
}

fn parse_memory(path: &Path) -> Result<BTreeMap<(String, String), f64>> {
    let file = path.display().to_string();
    let text = std::fs::read_to_string(path).with_context(|| format!("reading {file}"))?;
    let mut lines = non_empty_lines(&text);
    let (_, header) = lines.next().with_context(|| format!("{file}: file is empty"))?;
    let idx = header_indices(header, &["HashOwner", "HashApp", "AverageAllocatedMb"], &file)?;
    let width = header.split(',').count();
    let mut out: BTreeMap<(String, String), f64> = BTreeMap::new();
    let mut seen: BTreeMap<(String, String), usize> = BTreeMap::new();
    for (line, text_line) in lines {
        let cols: Vec<&str> = text_line.split(',').map(str::trim).collect();
        if cols.len() != width {
            bail!("{file}:{line}: expected {width} columns, got {}", cols.len());
        }
        let key = (cols[idx["HashOwner"]].to_string(), cols[idx["HashApp"]].to_string());
        if let Some(prev) = seen.insert(key.clone(), line) {
            bail!(
                "{file}:{line}: duplicate app {} (first seen at line {prev})",
                cols[idx["HashApp"]]
            );
        }
        let mb = parse_field(&cols, idx["AverageAllocatedMb"], &file, line, "allocated MB")?;
        if mb <= 0.0 {
            bail!("{file}:{line}: AverageAllocatedMb must be positive, got {mb}");
        }
        out.insert(key, mb);
    }
    if out.is_empty() {
        bail!("{file}: contains a header but no data rows");
    }
    Ok(out)
}

fn short_hash(s: &str) -> String {
    s.chars().take(8).collect()
}

impl AzureDataset {
    /// Load and join the three dataset CSVs from `dir`. Every function in
    /// the invocations file must have a durations row — inconsistent
    /// function identities across those files are line-numbered errors, as
    /// are malformed rows, missing columns and empty files. Apps absent
    /// from the (subset-sampled) memory file take [`DEFAULT_MEMORY_MB`].
    pub fn load(dir: &Path) -> Result<AzureDataset> {
        let inv_path = find_csv(dir, "invocations_per_function")?;
        let dur_path = find_csv(dir, "function_durations_percentiles")?;
        let mem_path = find_csv(dir, "app_memory_percentiles")?;
        let inv_file = inv_path.display().to_string();
        let rows = parse_invocations(&inv_path)?;
        let durations = parse_durations(&dur_path)?;
        let memory = parse_memory(&mem_path)?;

        let mut functions = Vec::with_capacity(rows.len());
        for row in &rows {
            let (owner, app, func) = &row.key;
            let d = durations.get(&row.key).with_context(|| {
                format!(
                    "{inv_file}:{}: function {} has no row in {} \
                     (inconsistent function ids across the dataset files)",
                    row.line,
                    short_hash(func),
                    dur_path.display()
                )
            })?;
            // The memory file only covers a sampled subset of apps in the
            // published dataset; absent apps take the documented default.
            let mb = memory
                .get(&(owner.clone(), app.clone()))
                .copied()
                .unwrap_or(DEFAULT_MEMORY_MB);
            let total: f64 = row.counts.iter().sum();
            let warm = (d.avg_ms / 1000.0).max(1e-3);
            let cold = warm + ((d.p99_ms - d.p50_ms) / 1000.0).max(COLD_OVERHEAD_FLOOR);
            functions.push(IngestedFunction {
                name: short_hash(func),
                minute_rates: Arc::new(row.counts.iter().map(|c| c / 60.0).collect()),
                total_invocations: total.round() as u64,
                warm_service_mean: warm,
                cold_service_mean: cold,
                memory_mb: mb,
            });
        }
        let raw_functions = functions.len();
        Ok(AzureDataset {
            functions,
            source_dir: dir.display().to_string(),
            raw_functions,
            transforms: Vec::new(),
        })
    }

    /// Total mean rate (req/s) across all functions.
    pub fn total_mean_rate(&self) -> f64 {
        self.functions.iter().map(IngestedFunction::mean_rate).sum()
    }

    /// Keep the `k` most-invoked functions (descending by total
    /// invocations, name-tiebroken for determinism).
    pub fn top_k(mut self, k: usize) -> AzureDataset {
        self.functions.sort_by(|a, b| {
            b.total_invocations.cmp(&a.total_invocations).then_with(|| a.name.cmp(&b.name))
        });
        self.functions.truncate(k);
        self.transforms.push(format!("top_k({k})"));
        self
    }

    /// Keep `len` functions starting at index `start` (current order).
    pub fn slice(mut self, start: usize, len: usize) -> Result<AzureDataset> {
        if len == 0 {
            bail!("slice length must be at least 1");
        }
        let end = start.checked_add(len).filter(|&e| e <= self.functions.len());
        let Some(end) = end else {
            bail!(
                "slice [{start}, {start}+{len}) is out of range: the trace has {} functions",
                self.functions.len()
            );
        };
        self.functions = self.functions[start..end].to_vec();
        self.transforms.push(format!("slice({start}, {len})"));
        Ok(self)
    }

    /// Multiply every function's rate profile (and invocation total) by
    /// `factor` — load scaling for what-if studies.
    pub fn scale_rates(mut self, factor: f64) -> Result<AzureDataset> {
        if !(factor > 0.0 && factor.is_finite()) {
            bail!("scale factor must be a positive finite number, got {factor}");
        }
        for f in &mut self.functions {
            f.minute_rates = Arc::new(f.minute_rates.iter().map(|r| r * factor).collect());
            f.total_invocations = (f.total_invocations as f64 * factor).round() as u64;
        }
        self.transforms.push(format!("scale_rates({factor})"));
        Ok(self)
    }

    /// One-line provenance summary (directory, selection, transforms).
    pub fn describe(&self) -> String {
        let transforms = if self.transforms.is_empty() {
            String::new()
        } else {
            format!(" [{}]", self.transforms.join(", "))
        };
        format!(
            "{} ({} of {} functions){transforms}",
            self.source_dir,
            self.functions.len(),
            self.raw_functions
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn write_dataset(dir: &Path, inv: &str, dur: &str, mem: &str) {
        std::fs::create_dir_all(dir).unwrap();
        std::fs::write(dir.join("invocations_per_function.csv"), inv).unwrap();
        std::fs::write(dir.join("function_durations_percentiles.csv"), dur).unwrap();
        std::fs::write(dir.join("app_memory_percentiles.csv"), mem).unwrap();
    }

    const DUR_HEADER: &str = "HashOwner,HashApp,HashFunction,Average,Count,Minimum,Maximum,\
percentile_Average_0,percentile_Average_1,percentile_Average_25,percentile_Average_50,\
percentile_Average_75,percentile_Average_99,percentile_Average_100";

    fn tmp_dir(tag: &str) -> PathBuf {
        let dir = std::env::temp_dir()
            .join(format!("simfaas-azure-{}-{tag}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        dir
    }

    #[test]
    fn loads_and_joins_a_minimal_dataset() {
        let dir = tmp_dir("ok");
        write_dataset(
            &dir,
            "HashOwner,HashApp,HashFunction,Trigger,1,2,3\n\
             o1,a1,f1aaaaaaaa,http,2,0,1\n\
             o1,a1,f2bbbbbbbb,timer,0,6,0\n",
            &format!(
                "{DUR_HEADER}\n\
                 o1,a1,f1aaaaaaaa,100,3,1,500,1,2,50,80,120,400,500\n\
                 o1,a1,f2bbbbbbbb,2000,6,100,9000,100,200,1000,1800,2500,8000,9000\n"
            ),
            "HashOwner,HashApp,SampleCount,AverageAllocatedMb\no1,a1,10,170\n",
        );
        let ds = AzureDataset::load(&dir).unwrap();
        assert_eq!(ds.functions.len(), 2);
        assert_eq!(ds.raw_functions, 2);
        let f1 = &ds.functions[0];
        assert_eq!(f1.name, "f1aaaaaa");
        assert_eq!(f1.total_invocations, 3);
        assert_eq!(f1.minute_rates.as_slice(), &[2.0 / 60.0, 0.0, 1.0 / 60.0]);
        // warm = 100 ms, cold = warm + (400 - 80) ms = 0.42 s.
        assert!((f1.warm_service_mean - 0.1).abs() < 1e-12);
        assert!((f1.cold_service_mean - 0.42).abs() < 1e-12);
        assert_eq!(f1.memory_mb, 170.0);
        // f2's spread (8000 - 1800 = 6200 ms) dominates the floor too.
        let f2 = &ds.functions[1];
        assert!((f2.cold_service_mean - (2.0 + 6.2)).abs() < 1e-12);
        assert!((ds.total_mean_rate() - (3.0 + 6.0) / 180.0).abs() < 1e-12);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn cold_overhead_floor_applies_on_tight_percentiles() {
        let dir = tmp_dir("floor");
        write_dataset(
            &dir,
            "HashOwner,HashApp,HashFunction,Trigger,1\no1,a1,f1,http,1\n",
            &format!("{DUR_HEADER}\no1,a1,f1,100,1,90,110,90,91,95,100,105,110,110\n"),
            "HashOwner,HashApp,SampleCount,AverageAllocatedMb\no1,a1,1,128\n",
        );
        let ds = AzureDataset::load(&dir).unwrap();
        // Spread (110 - 100 = 10 ms) is below the floor.
        assert!(
            (ds.functions[0].cold_service_mean
                - (ds.functions[0].warm_service_mean + COLD_OVERHEAD_FLOOR))
                .abs()
                < 1e-12
        );
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn transforms_select_and_scale() {
        let dir = tmp_dir("tf");
        write_dataset(
            &dir,
            "HashOwner,HashApp,HashFunction,Trigger,1,2\n\
             o1,a1,hot,http,30,30\n\
             o1,a1,mid,http,5,5\n\
             o1,a1,cold,http,1,0\n",
            &format!(
                "{DUR_HEADER}\n\
                 o1,a1,hot,100,60,1,500,1,2,50,80,120,400,500\n\
                 o1,a1,mid,100,10,1,500,1,2,50,80,120,400,500\n\
                 o1,a1,cold,100,1,1,500,1,2,50,80,120,400,500\n"
            ),
            "HashOwner,HashApp,SampleCount,AverageAllocatedMb\no1,a1,10,128\n",
        );
        let ds = AzureDataset::load(&dir).unwrap();
        let top = ds.clone().top_k(2);
        assert_eq!(top.functions.len(), 2);
        assert_eq!(top.functions[0].name, "hot");
        assert_eq!(top.functions[1].name, "mid");
        assert_eq!(top.raw_functions, 3);
        assert!(top.describe().contains("top_k(2)"), "{}", top.describe());

        let sliced = ds.clone().slice(1, 2).unwrap();
        assert_eq!(sliced.functions[0].name, "mid");
        assert_eq!(sliced.functions[1].name, "cold");
        assert!(ds.clone().slice(2, 5).is_err());

        let scaled = ds.clone().scale_rates(2.0).unwrap();
        assert_eq!(scaled.functions[0].total_invocations, 120);
        assert!((scaled.total_mean_rate() - 2.0 * ds.total_mean_rate()).abs() < 1e-12);
        assert!(ds.clone().scale_rates(0.0).is_err());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn malformed_rows_report_file_and_line() {
        let dir = tmp_dir("badrow");
        write_dataset(
            &dir,
            "HashOwner,HashApp,HashFunction,Trigger,1,2\n\
             o1,a1,f1,http,2,1\n\
             o1,a1,f2,http,2,oops\n",
            &format!("{DUR_HEADER}\no1,a1,f1,100,3,1,500,1,2,50,80,120,400,500\n"),
            "HashOwner,HashApp,SampleCount,AverageAllocatedMb\no1,a1,10,170\n",
        );
        let err = format!("{:#}", AzureDataset::load(&dir).unwrap_err());
        assert!(err.contains(":3:"), "{err}");
        assert!(err.contains("oops"), "{err}");
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn wrong_column_count_reports_line() {
        let dir = tmp_dir("cols");
        write_dataset(
            &dir,
            "HashOwner,HashApp,HashFunction,Trigger,1,2\no1,a1,f1,http,2\n",
            &format!("{DUR_HEADER}\no1,a1,f1,100,3,1,500,1,2,50,80,120,400,500\n"),
            "HashOwner,HashApp,SampleCount,AverageAllocatedMb\no1,a1,10,170\n",
        );
        let err = format!("{:#}", AzureDataset::load(&dir).unwrap_err());
        assert!(err.contains(":2:") && err.contains("columns"), "{err}");
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn missing_percentile_column_is_a_header_error() {
        let dir = tmp_dir("hdr");
        write_dataset(
            &dir,
            "HashOwner,HashApp,HashFunction,Trigger,1\no1,a1,f1,http,1\n",
            "HashOwner,HashApp,HashFunction,Average,percentile_Average_50\n\
             o1,a1,f1,100,80\n",
            "HashOwner,HashApp,SampleCount,AverageAllocatedMb\no1,a1,10,170\n",
        );
        let err = format!("{:#}", AzureDataset::load(&dir).unwrap_err());
        assert!(err.contains("percentile_Average_99"), "{err}");
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn empty_files_are_errors() {
        let dir = tmp_dir("empty");
        write_dataset(
            &dir,
            "HashOwner,HashApp,HashFunction,Trigger,1\n",
            &format!("{DUR_HEADER}\no1,a1,f1,100,3,1,500,1,2,50,80,120,400,500\n"),
            "HashOwner,HashApp,SampleCount,AverageAllocatedMb\no1,a1,10,170\n",
        );
        let err = format!("{:#}", AzureDataset::load(&dir).unwrap_err());
        assert!(err.contains("no data rows"), "{err}");

        std::fs::write(dir.join("invocations_per_function.csv"), "").unwrap();
        let err = format!("{:#}", AzureDataset::load(&dir).unwrap_err());
        assert!(err.contains("empty"), "{err}");
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn inconsistent_ids_across_files_are_line_numbered_errors() {
        // f2 invoked but absent from the durations file.
        let dir = tmp_dir("ids");
        write_dataset(
            &dir,
            "HashOwner,HashApp,HashFunction,Trigger,1\n\
             o1,a1,f1,http,1\n\
             o1,a1,f2,http,1\n",
            &format!("{DUR_HEADER}\no1,a1,f1,100,3,1,500,1,2,50,80,120,400,500\n"),
            "HashOwner,HashApp,SampleCount,AverageAllocatedMb\no1,a1,10,170\n",
        );
        let err = format!("{:#}", AzureDataset::load(&dir).unwrap_err());
        assert!(err.contains(":3:") && err.contains("f2"), "{err}");
        assert!(err.contains("inconsistent"), "{err}");

        // An app absent from the memory file is NOT an error — the real
        // dataset samples memory for a subset of apps — it defaults.
        write_dataset(
            &dir,
            "HashOwner,HashApp,HashFunction,Trigger,1\no1,a2,f1,http,1\n",
            &format!("{DUR_HEADER}\no1,a2,f1,100,3,1,500,1,2,50,80,120,400,500\n"),
            "HashOwner,HashApp,SampleCount,AverageAllocatedMb\no1,a1,10,170\n",
        );
        let ds = AzureDataset::load(&dir).unwrap();
        assert_eq!(ds.functions[0].memory_mb, DEFAULT_MEMORY_MB);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn duplicate_keys_are_errors() {
        let dir = tmp_dir("dup");
        write_dataset(
            &dir,
            "HashOwner,HashApp,HashFunction,Trigger,1\n\
             o1,a1,f1,http,1\n\
             o1,a1,f1,timer,2\n",
            &format!("{DUR_HEADER}\no1,a1,f1,100,3,1,500,1,2,50,80,120,400,500\n"),
            "HashOwner,HashApp,SampleCount,AverageAllocatedMb\no1,a1,10,170\n",
        );
        let err = format!("{:#}", AzureDataset::load(&dir).unwrap_err());
        assert!(err.contains("duplicate") && err.contains(":3:"), "{err}");
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn missing_files_name_the_expected_layout() {
        let dir = tmp_dir("nofiles");
        std::fs::create_dir_all(&dir).unwrap();
        let err = format!("{:#}", AzureDataset::load(&dir).unwrap_err());
        assert!(err.contains("invocations_per_function"), "{err}");
        std::fs::remove_dir_all(&dir).ok();
    }
}
