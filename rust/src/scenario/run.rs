//! `run_scenario` — the single entry point that executes a
//! [`ScenarioSpec`] on the right engine, plus the [`ScenarioReport`] it
//! returns.
//!
//! Every arm reproduces what the corresponding CLI subcommand used to
//! hand-wire, bit for bit: the same config construction, the same engine
//! call, the same report text (the CLI now routes through here, and the
//! regression tests in this module pin scenario output against direct
//! engine invocation).

use super::spec::{
    CostSpec, ExperimentSpec, ObservabilitySpec, OutputFormat, ScenarioSpec, SourceSpec,
};
use crate::analytical::{self, ComparisonReport};
use crate::cost::{estimate, scale_to, CostEstimate, FunctionConfig, PricingTable};
use crate::figures;
use crate::fleet::{fleet_cost, FleetConfig, FleetCostReport, FleetResults};
use crate::output::json::{fleet_to_json, results_to_json, JsonValue};
use crate::output::{ascii_lines, Series, Table};
use crate::sim::ensemble::{run_ensemble, EnsembleOpts, EnsembleResults, MetricCi};
use crate::sim::{
    InitialState, Process, Rng, ServerlessSimulator, ServerlessTemporalSimulator, SimResults,
    TemporalResults,
};
use crate::control::ControlReport;
use crate::telemetry::{
    chrome_trace, write_control_csv, write_samples_csv, write_spans_jsonl, Observer, StateSample,
    TelemetryRecorder,
};
use crate::whatif::{self, PolicyOutcome};
use crate::workload::{AzureDataset, SyntheticTrace, TraceProvenance, TraceSource};
use anyhow::{bail, Context, Result};

/// Priced view of a single-function run (the `cost` axis output).
#[derive(Debug, Clone)]
pub struct CostBlock {
    pub estimate: CostEstimate,
    /// The estimate scaled to `CostSpec::scale_to_window`, when set.
    pub scaled: Option<CostEstimate>,
}

/// What the observability axis captured: record counts plus where the
/// export files went (all `None` when no `record_trace` path was set).
#[derive(Debug, Clone)]
pub struct TelemetrySummary {
    /// Captured span records across every function.
    pub spans: usize,
    /// Captured internal-state samples across every function.
    pub samples: usize,
    /// The span JSONL destination (`record_trace` verbatim), when written.
    pub span_path: Option<String>,
    /// The Chrome trace-event JSON destination, when written.
    pub perfetto_path: Option<String>,
    /// The time-series CSV destination, when written.
    pub metrics_path: Option<String>,
    /// The control-tick CSV destination, when written (controlled fleet
    /// runs with a `record_trace` path only).
    pub control_path: Option<String>,
}

/// What [`run_scenario`] hands back: the engine results for the spec's
/// experiment, renderable as the CLI's tables ([`ScenarioReport::render`])
/// or as JSON ([`ScenarioReport::to_json`]).
pub enum ScenarioReport {
    Steady {
        results: SimResults,
        cost: Option<CostBlock>,
        /// Set when the spec carries an observability axis.
        telemetry: Option<TelemetrySummary>,
    },
    Temporal { replications: usize, results: TemporalResults },
    EnsembleSingle { results: EnsembleResults },
    EnsembleGrid { replications: usize, grid: Vec<(f64, EnsembleResults)> },
    Sweep { rates: Vec<f64>, series: Vec<(f64, Vec<(f64, f64)>)> },
    Compare { report: ComparisonReport },
    Fleet {
        policy: String,
        results: FleetResults,
        cost: FleetCostReport,
        top_k: usize,
        /// Where the tenant mix came from (synthetic seed vs ingested
        /// trace) — rendered in the table and recorded in the JSON.
        provenance: TraceProvenance,
        /// Set when the spec carries an observability axis.
        telemetry: Option<TelemetrySummary>,
    },
    FleetComparison {
        functions: usize,
        outcomes: Vec<PolicyOutcome>,
        /// Workload provenance, as in [`ScenarioReport::Fleet`].
        provenance: TraceProvenance,
    },
}

/// Build the [`TraceSource`] a fleet spec asks for: the synthetic mix by
/// default (generated from the run seed — the historical construction,
/// bit-identical), or an ingested Azure dataset with its transform chain
/// (`slice`, then `top_k`, then `scale_rate`).
fn build_trace_source(spec: &ScenarioSpec, functions: usize) -> Result<TraceSource> {
    match &spec.workload.source {
        Some(SourceSpec::AzureDataset { dir, top_k, slice, scale_rate }) => {
            let mut ds = AzureDataset::load(std::path::Path::new(dir))?;
            if let Some((start, len)) = slice {
                ds = ds.slice(*start, *len)?;
            }
            if let Some(k) = top_k {
                ds = ds.top_k(*k);
            }
            if *scale_rate != 1.0 {
                ds = ds.scale_rates(*scale_rate)?;
            }
            if ds.functions.is_empty() {
                bail!("workload.source: no functions left after the transform chain");
            }
            Ok(TraceSource::AzureDataset(ds))
        }
        Some(SourceSpec::Synthetic) | None => {
            let mut rng = Rng::new(spec.run.seed);
            Ok(TraceSource::Synthetic(SyntheticTrace::generate(functions, &mut rng)))
        }
    }
}

/// Execute a scenario. Validates first, so malformed specs fail with a
/// message naming the field rather than an engine panic. Deterministic:
/// equal specs produce bit-identical reports.
pub fn run_scenario(spec: &ScenarioSpec) -> Result<ScenarioReport> {
    spec.validate()?;
    Ok(match &spec.experiment {
        ExperimentSpec::Steady => {
            let mut sim = ServerlessSimulator::new(spec.sim_config());
            if let Some(obs) = &spec.observability {
                sim.set_observer(Observer::recording(0, obs.metrics_interval));
            }
            let results = sim.run();
            let telemetry = match &spec.observability {
                Some(obs) => {
                    let recorder = sim.take_recorder().unwrap_or_default();
                    Some(export_telemetry(&[recorder], &[spec.name.clone()], obs)?)
                }
                None => None,
            };
            let cost = spec.cost.as_ref().map(|c| price(&results, c));
            ScenarioReport::Steady { results, cost, telemetry }
        }
        ExperimentSpec::Temporal { replications, sample_interval, warm_pool } => {
            let mut cfg = spec.sim_config();
            cfg.sample_interval = sample_interval.unwrap_or(cfg.horizon / 100.0);
            let init = if *warm_pool > 0 {
                InitialState::warm_pool(*warm_pool)
            } else {
                InitialState::empty()
            };
            let results =
                ServerlessTemporalSimulator::new(cfg, init, *replications).run();
            ScenarioReport::Temporal { replications: *replications, results }
        }
        ExperimentSpec::Ensemble { replications, threads, thresholds } => {
            let cfg = spec.sim_config();
            let opts = EnsembleOpts {
                replications: *replications,
                threads: *threads,
                root_seed: cfg.seed,
            };
            if thresholds.is_empty() {
                ScenarioReport::EnsembleSingle { results: run_ensemble(&cfg, &opts) }
            } else {
                ScenarioReport::EnsembleGrid {
                    replications: *replications,
                    grid: whatif::expiration_threshold_ensemble(&cfg, thresholds, &opts),
                }
            }
        }
        ExperimentSpec::Sweep { rates, thresholds } => {
            let base = spec.sim_config();
            let series = figures::fig5_sweep_from(
                &base,
                rates,
                thresholds,
                spec.run.horizon,
                spec.run.seed,
            );
            ScenarioReport::Sweep { rates: rates.clone(), series }
        }
        ExperimentSpec::Compare { service_mean, markovian_expiration } => {
            let mut cfg = spec.sim_config();
            cfg.cold_service = Process::exp_mean(*service_mean);
            cfg.warm_service = Process::exp_mean(*service_mean);
            let report = if *markovian_expiration {
                analytical::compare_steady_state_markovian(&cfg, *service_mean)
            } else {
                analytical::compare_steady_state(&cfg, *service_mean)
            };
            ScenarioReport::Compare { report }
        }
        ExperimentSpec::Fleet(f) => {
            // The workload enters through the TraceSource seam: the
            // synthetic mix reproduces the historical construction (one
            // RNG seeded from the run seed generates the profiles, the
            // fleet derives per-function streams from the same root seed,
            // bit-identical through the streaming path), while an
            // ingested Azure dataset replaces it wholesale.
            let source = build_trace_source(spec, f.functions)?;
            let provenance = source.provenance();
            let mut cfg = FleetConfig::from_source(
                &source,
                spec.run.horizon,
                spec.run.skip_initial,
                spec.run.seed,
                f.policy.build(),
            );
            cfg.threads = f.threads;
            cfg.fleet_max_concurrency = f.fleet_cap;
            cfg.cluster = f.cluster.clone();
            cfg.capacity_domains = f.capacity_domains;
            cfg.prewarm_lead = f.prewarm_lead;
            cfg.controller = f.controller;
            if let Some(r) = &spec.reliability {
                cfg.fault = r.fault.clone();
                cfg.retry = r.retry.clone();
            }
            if matches!(source, TraceSource::Synthetic(_)) {
                // The synthetic mix bills every function at the spec's
                // memory; ingested functions keep their dataset memory.
                for func in &mut cfg.functions {
                    func.memory_mb = f.memory_mb;
                }
            }
            let provider = spec
                .cost
                .as_ref()
                .map(|c| c.provider)
                .unwrap_or(crate::cost::Provider::AwsLambda);
            let pricing = PricingTable::for_provider(provider);
            // Comparison mode whenever any policy grid is given — a spec
            // listing only `compare_extra` policies still compares.
            if !f.compare_thresholds.is_empty() || !f.compare_extra.is_empty() {
                let extra: Vec<_> = f.compare_extra.iter().map(|p| p.build()).collect();
                let outcomes = whatif::keepalive_policy_comparison(
                    &cfg,
                    &f.compare_thresholds,
                    &extra,
                    &pricing,
                );
                ScenarioReport::FleetComparison {
                    functions: cfg.functions.len(),
                    outcomes,
                    provenance,
                }
            } else {
                if let Some(obs) = &spec.observability {
                    cfg.telemetry = Some(obs.metrics_interval);
                }
                let results = cfg.run();
                let telemetry = match (&spec.observability, &results.telemetry) {
                    (Some(obs), Some(recs)) => {
                        let mut t = export_telemetry(recs, &results.names, obs)?;
                        if let (Some(path), Some(ctl)) =
                            (&obs.record_trace, &results.control)
                        {
                            let stem = path.strip_suffix(".jsonl").unwrap_or(path);
                            let control_path = format!("{stem}.control.csv");
                            let mut csv = Vec::new();
                            write_control_csv(&mut csv, &ctl.samples)?;
                            std::fs::write(&control_path, &csv).with_context(|| {
                                format!("writing control csv {control_path}")
                            })?;
                            t.control_path = Some(control_path);
                        }
                        Some(t)
                    }
                    _ => None,
                };
                let cost = fleet_cost(&cfg, &results, &pricing);
                ScenarioReport::Fleet {
                    policy: cfg.policy.describe(),
                    results,
                    cost,
                    top_k: f.top_k,
                    provenance,
                    telemetry,
                }
            }
        }
    })
}

/// Run a scenario and format it per the spec's output axis — what the CLI
/// prints verbatim.
pub fn run_scenario_to_string(spec: &ScenarioSpec) -> Result<String> {
    let report = run_scenario(spec)?;
    Ok(match spec.output.format {
        OutputFormat::Table => report.render(spec),
        OutputFormat::Json => format!("{}\n", report.to_json(spec)),
    })
}

fn price(results: &SimResults, c: &CostSpec) -> CostBlock {
    let f = FunctionConfig {
        memory_mb: c.memory_mb,
        external_per_request: c.external_per_request,
    };
    let est = estimate(results, &f, &PricingTable::for_provider(c.provider));
    CostBlock { estimate: est, scaled: c.scale_to_window.map(|w| scale_to(&est, w)) }
}

/// Summarize captured telemetry and, when `record_trace` is set, write the
/// three export files: the span JSONL at the given path verbatim, the
/// Chrome trace-event JSON at `<stem>.perfetto.json`, and the time-series
/// CSV at `<stem>.metrics.csv` (stem = the path minus a `.jsonl` suffix).
/// Recorders arrive in function order, so every export is byte-identical
/// across thread counts.
fn export_telemetry(
    recorders: &[TelemetryRecorder],
    names: &[String],
    obs: &ObservabilitySpec,
) -> Result<TelemetrySummary> {
    let spans = recorders.iter().map(|r| r.spans.len()).sum();
    let samples = recorders.iter().map(|r| r.samples.len()).sum();
    let mut summary = TelemetrySummary {
        spans,
        samples,
        span_path: None,
        perfetto_path: None,
        metrics_path: None,
        control_path: None,
    };
    if let Some(path) = &obs.record_trace {
        let stem = path.strip_suffix(".jsonl").unwrap_or(path);
        let mut jsonl = Vec::new();
        for rec in recorders {
            write_spans_jsonl(&mut jsonl, &rec.spans)?;
        }
        std::fs::write(path, &jsonl)
            .with_context(|| format!("writing span trace {path}"))?;
        summary.span_path = Some(path.clone());
        let perfetto_path = format!("{stem}.perfetto.json");
        let doc = chrome_trace(recorders, names);
        std::fs::write(&perfetto_path, format!("{doc}\n"))
            .with_context(|| format!("writing perfetto trace {perfetto_path}"))?;
        summary.perfetto_path = Some(perfetto_path);
        let metrics_path = format!("{stem}.metrics.csv");
        let all: Vec<StateSample> =
            recorders.iter().flat_map(|r| r.samples.iter().cloned()).collect();
        let mut csv = Vec::new();
        write_samples_csv(&mut csv, &all)?;
        std::fs::write(&metrics_path, &csv)
            .with_context(|| format!("writing metrics csv {metrics_path}"))?;
        summary.metrics_path = Some(metrics_path);
    }
    Ok(summary)
}

/// The telemetry footer rendered under steady/fleet tables: counts plus
/// where the exports went.
fn render_telemetry(t: &TelemetrySummary) -> String {
    let mut s = format!("telemetry: {} spans, {} samples\n", t.spans, t.samples);
    if let (Some(spans), Some(perfetto), Some(metrics)) =
        (&t.span_path, &t.perfetto_path, &t.metrics_path)
    {
        s.push_str(&format!("telemetry files: {spans} | {perfetto} | {metrics}\n"));
    }
    if let Some(control) = &t.control_path {
        s.push_str(&format!("control ticks: {control}\n"));
    }
    s
}

fn telemetry_json(t: &TelemetrySummary) -> JsonValue {
    let mut o = JsonValue::object();
    o.set("spans", t.spans).set("samples", t.samples);
    if let Some(p) = &t.span_path {
        o.set("span_path", p.as_str());
    }
    if let Some(p) = &t.perfetto_path {
        o.set("perfetto_path", p.as_str());
    }
    if let Some(p) = &t.metrics_path {
        o.set("metrics_path", p.as_str());
    }
    if let Some(p) = &t.control_path {
        o.set("control_path", p.as_str());
    }
    o
}

impl ScenarioReport {
    /// Render the human-readable report — character-identical to what the
    /// pre-scenario CLI subcommands printed.
    pub fn render(&self, spec: &ScenarioSpec) -> String {
        let mut s = String::new();
        match self {
            ScenarioReport::Steady { results, cost, telemetry } => {
                match cost {
                    // The `cost` subcommand's report: pricing table + summary.
                    Some(block) => s.push_str(&render_cost(results, block)),
                    None => s.push_str(&results.to_string()),
                }
                if let Some(t) = telemetry {
                    s.push_str(&render_telemetry(t));
                }
            }
            ScenarioReport::Temporal { replications, results } => {
                let band = results.average_count_band();
                let series = vec![
                    Series::new("mean", band.iter().map(|&(t, m, _)| (t, m)).collect()),
                    Series::new("mean+ci", band.iter().map(|&(t, m, h)| (t, m + h)).collect()),
                    Series::new("mean-ci", band.iter().map(|&(t, m, h)| (t, m - h)).collect()),
                ];
                s.push_str(&format!(
                    "Average instance count over time ({replications} runs, 95% CI):\n"
                ));
                s.push_str(&ascii_lines(&series, 72, 18));
                let (m, hw) = results.avg_server_count_ci;
                s.push_str(&format!("final avg server count: {m:.4} ± {hw:.4} (95% CI)\n"));
                let (pc, pch) = results.cold_start_prob_ci;
                s.push_str(&format!(
                    "cold start probability: {:.4}% ± {:.4}%\n",
                    pc * 100.0,
                    pch * 100.0
                ));
            }
            ScenarioReport::EnsembleSingle { results } => {
                s.push_str(&results.summary().to_table());
            }
            ScenarioReport::EnsembleGrid { replications, grid } => {
                s.push_str(&format!(
                    "{replications} replications per threshold, 95% CI half-widths:\n"
                ));
                let mut t =
                    Table::new(vec!["threshold s", "p_cold %", "avg servers", "waste %"]);
                for (th, res) in grid {
                    let p = res.ci_of(|r| r.cold_start_prob);
                    let sv = res.ci_of(|r| r.avg_server_count);
                    let w = res.ci_of(|r| r.wasted_capacity);
                    t.row(vec![
                        format!("{th:.0}"),
                        format!("{:.4} ± {:.4}", p.mean * 100.0, p.ci_half * 100.0),
                        format!("{:.4} ± {:.4}", sv.mean, sv.ci_half),
                        format!("{:.3} ± {:.3}", w.mean * 100.0, w.ci_half * 100.0),
                    ]);
                }
                s.push_str(&t.render());
            }
            ScenarioReport::Sweep { rates, series } => {
                let mut table = Table::new(
                    std::iter::once("rate".to_string())
                        .chain(series.iter().map(|(th, _)| format!("p_cold@{th}s")))
                        .collect::<Vec<_>>(),
                );
                for (i, &rate) in rates.iter().enumerate() {
                    let mut row = vec![rate];
                    for (_, points) in series {
                        row.push(points[i].1 * 100.0);
                    }
                    table.row_f64(&row, 4);
                }
                s.push_str(
                    "Cold start probability (%) vs arrival rate x expiration threshold:\n",
                );
                s.push_str(&table.render());
                let plotted: Vec<Series> = series
                    .iter()
                    .map(|(th, pts)| Series::new(format!("{th} s"), pts.clone()))
                    .collect();
                s.push_str(&ascii_lines(&plotted, 72, 18));
            }
            ScenarioReport::Compare { report } => {
                s.push_str(&report.to_table());
            }
            ScenarioReport::Fleet { policy, results, cost, top_k, provenance, telemetry } => {
                let horizon = spec.run.horizon;
                let seed = spec.run.seed;
                s.push_str(&format!(
                    "fleet: {} functions under {policy} (horizon {horizon} s, seed {seed})\n",
                    results.per_function.len()
                ));
                s.push_str(&format!("workload: {}\n", provenance.describe()));
                if let ExperimentSpec::Fleet(f) = &spec.experiment {
                    if let Some(cl) = &f.cluster {
                        s.push_str(&format!(
                            "cluster: {} hosts x {} MB / {} cpus, scheduler {}\n",
                            cl.hosts,
                            cl.host_memory_mb,
                            cl.host_cpus,
                            cl.scheduler.as_str()
                        ));
                        for w in cl.drain_horizon_warnings(spec.run.horizon) {
                            s.push_str(&w);
                            s.push('\n');
                        }
                    }
                    if f.capacity_domains > 1 {
                        s.push_str(&format!(
                            "capacity domains: {} (cap and hosts sharded; per-domain deterministic)\n",
                            f.capacity_domains
                        ));
                    }
                }
                s.push_str(&results.aggregate.to_table());
                s.push_str(&format!(
                    "developer cost ${:.4} (requests ${:.4} + runtime ${:.4}) | provider infra ${:.4}\n",
                    cost.total.developer_total(),
                    cost.total.request_charges,
                    cost.total.runtime_charges,
                    cost.total.provider_infra_cost
                ));
                if let Some(ctl) = &results.control {
                    for line in ctl.to_lines() {
                        s.push_str(&line);
                        s.push('\n');
                    }
                }
                let top = (*top_k).min(results.per_function.len());
                if top > 0 {
                    let mut order: Vec<usize> = (0..results.per_function.len()).collect();
                    order.sort_by(|&a, &b| {
                        results.per_function[b]
                            .total_requests
                            .cmp(&results.per_function[a].total_requests)
                    });
                    let mut t = Table::new(vec![
                        "function",
                        "requests",
                        "p_cold %",
                        "avg servers",
                        "billed s",
                    ]);
                    for &i in order.iter().take(top) {
                        let r = &results.per_function[i];
                        t.row(vec![
                            results.names[i].clone(),
                            format!("{}", r.total_requests),
                            format!("{:.4}", r.cold_start_prob * 100.0),
                            format!("{:.4}", r.avg_server_count),
                            format!("{:.1}", r.billed_instance_seconds),
                        ]);
                    }
                    s.push_str(&format!("top {top} functions by request volume:\n"));
                    s.push_str(&t.render());
                }
                if let Some(t) = telemetry {
                    s.push_str(&render_telemetry(t));
                }
            }
            ScenarioReport::FleetComparison { functions, outcomes, provenance } => {
                let horizon = spec.run.horizon;
                let seed = spec.run.seed;
                s.push_str(&format!(
                    "{functions} functions, horizon {horizon} s, seed {seed}: keep-alive policy comparison\n"
                ));
                s.push_str(&format!("workload: {}\n", provenance.describe()));
                let mut t = Table::new(vec![
                    "policy",
                    "p_cold %",
                    "rejected",
                    "avg servers",
                    "waste %",
                    "dev cost $",
                    "infra cost $",
                ]);
                for o in outcomes {
                    let a = &o.results.aggregate;
                    t.row(vec![
                        o.label.clone(),
                        format!("{:.4}", a.cold_start_prob * 100.0),
                        format!("{}", a.rejected_requests),
                        format!("{:.3}", a.avg_server_count),
                        format!("{:.2}", a.wasted_capacity * 100.0),
                        format!("{:.4}", o.cost.total.developer_total()),
                        format!("{:.4}", o.cost.total.provider_infra_cost),
                    ]);
                }
                s.push_str(&t.render());
            }
        }
        s
    }

    /// Serialize the report. For steady and fleet runs this is exactly the
    /// JSON the CLI's historical `--json` flag emitted; the other kinds
    /// gained JSON with the scenario layer.
    pub fn to_json(&self, spec: &ScenarioSpec) -> JsonValue {
        match self {
            ScenarioReport::Steady { results, cost, telemetry } => {
                let mut o = results_to_json(results);
                if let Some(block) = cost {
                    o.set("cost", cost_block_json(block));
                }
                if let Some(t) = telemetry {
                    o.set("telemetry", telemetry_json(t));
                }
                o
            }
            ScenarioReport::Temporal { replications, results } => {
                let mut o = JsonValue::object();
                let (m, hw) = results.avg_server_count_ci;
                let (pc, pch) = results.cold_start_prob_ci;
                o.set("replications", *replications)
                    .set("avg_server_count", ci_json(m, hw))
                    .set("cold_start_prob", ci_json(pc, pch))
                    .set(
                        "band",
                        JsonValue::Array(
                            results
                                .average_count_band()
                                .into_iter()
                                .map(|(t, mean, half)| {
                                    JsonValue::Array(vec![t.into(), mean.into(), half.into()])
                                })
                                .collect(),
                        ),
                    );
                o
            }
            ScenarioReport::EnsembleSingle { results } => summary_json(results),
            ScenarioReport::EnsembleGrid { replications, grid } => {
                let mut o = JsonValue::object();
                o.set("replications", *replications).set(
                    "thresholds",
                    JsonValue::Array(
                        grid.iter()
                            .map(|(th, res)| {
                                let mut e = summary_json(res);
                                e.set("threshold", *th);
                                e
                            })
                            .collect(),
                    ),
                );
                o
            }
            ScenarioReport::Sweep { rates, series } => {
                let mut o = JsonValue::object();
                o.set("rates", rates.clone()).set(
                    "series",
                    JsonValue::Array(
                        series
                            .iter()
                            .map(|(th, pts)| {
                                let mut e = JsonValue::object();
                                e.set("threshold", *th).set(
                                    "points",
                                    JsonValue::Array(
                                        pts.iter()
                                            .map(|&(r, p)| {
                                                JsonValue::Array(vec![r.into(), p.into()])
                                            })
                                            .collect(),
                                    ),
                                );
                                e
                            })
                            .collect(),
                    ),
                );
                o
            }
            ScenarioReport::Compare { report } => {
                let mut o = JsonValue::object();
                o.set(
                    "rows",
                    JsonValue::Array(
                        report
                            .rows
                            .iter()
                            .map(|r| {
                                let mut e = JsonValue::object();
                                e.set("metric", r.name)
                                    .set("analytical", r.analytical)
                                    .set("simulated", r.simulated)
                                    .set("pct_error", r.pct_error());
                                e
                            })
                            .collect(),
                    ),
                );
                o
            }
            ScenarioReport::Fleet { results, cost, provenance, telemetry, .. } => {
                let mut o = fleet_to_json(results, Some(cost));
                o.set("trace", provenance_json(provenance));
                if let Some(t) = telemetry {
                    o.set("telemetry", telemetry_json(t));
                }
                if let Some(ctl) = &results.control {
                    o.set("control", control_json(ctl));
                }
                o
            }
            ScenarioReport::FleetComparison { outcomes, provenance, .. } => {
                let mut o = JsonValue::object();
                o.set("trace", provenance_json(provenance));
                o.set("experiment", spec.experiment.kind()).set(
                    "policies",
                    JsonValue::Array(
                        outcomes
                            .iter()
                            .map(|p| {
                                let a = &p.results.aggregate;
                                let mut e = JsonValue::object();
                                e.set("policy", p.label.as_str())
                                    .set("cold_start_prob", a.cold_start_prob)
                                    .set("rejected_requests", a.rejected_requests)
                                    .set("avg_server_count", a.avg_server_count)
                                    .set("wasted_capacity", a.wasted_capacity)
                                    .set("developer_total", p.cost.total.developer_total())
                                    .set(
                                        "provider_infra_cost",
                                        p.cost.total.provider_infra_cost,
                                    );
                                e
                            })
                            .collect(),
                    ),
                );
                o
            }
        }
    }
}

/// The §Control digest as a JSON object (per-tick samples stay in the
/// control CSV; the JSON carries the summary).
fn control_json(r: &ControlReport) -> JsonValue {
    let mut o = JsonValue::object();
    o.set("spec", r.spec.as_str())
        .set("setpoint", r.setpoint)
        .set("domains", r.domains)
        .set("ticks", r.ticks)
        .set("scale_up_events", r.scale_up_events)
        .set("scale_down_events", r.scale_down_events)
        .set("min_capacity", r.min_capacity)
        .set("max_capacity", r.max_capacity)
        .set("final_capacity", r.final_capacity)
        .set("pct_ticks_at_cap", r.pct_ticks_at_cap)
        .set("overshoot", r.overshoot)
        .set(
            "settling_time",
            r.settling_time.map(JsonValue::from).unwrap_or(JsonValue::Null),
        );
    o
}

/// Workload provenance as a JSON object (`{"source", "detail", "functions"}`).
fn provenance_json(p: &TraceProvenance) -> JsonValue {
    let mut o = JsonValue::object();
    o.set("source", p.kind.as_str())
        .set("detail", p.detail.as_str())
        .set("functions", p.functions);
    o
}

fn ci_json(mean: f64, ci_half: f64) -> JsonValue {
    let mut o = JsonValue::object();
    o.set("mean", mean).set("ci_half", ci_half);
    o
}

fn metric_ci_json(m: &MetricCi) -> JsonValue {
    ci_json(m.mean, m.ci_half)
}

/// The Table-1 CI summary as JSON (shared by single/grid ensemble output).
fn summary_json(results: &EnsembleResults) -> JsonValue {
    let sum = results.summary();
    let mut o = JsonValue::object();
    o.set("replications", sum.replications)
        .set("cold_start_prob", metric_ci_json(&sum.cold_start_prob))
        .set("rejection_prob", metric_ci_json(&sum.rejection_prob))
        .set("avg_server_count", metric_ci_json(&sum.avg_server_count))
        .set("avg_running_count", metric_ci_json(&sum.avg_running_count))
        .set("avg_idle_count", metric_ci_json(&sum.avg_idle_count))
        .set("wasted_capacity", metric_ci_json(&sum.wasted_capacity))
        .set("avg_response_time", metric_ci_json(&sum.avg_response_time))
        .set("response_p95", metric_ci_json(&sum.response_p95))
        .set("billed_instance_seconds", metric_ci_json(&sum.billed_instance_seconds));
    o
}

fn cost_block_json(block: &CostBlock) -> JsonValue {
    let mut o = JsonValue::object();
    o.set("window", cost_estimate_json(&block.estimate));
    if let Some(scaled) = &block.scaled {
        o.set("scaled", cost_estimate_json(scaled));
    }
    o
}

fn cost_estimate_json(e: &CostEstimate) -> JsonValue {
    let mut o = JsonValue::object();
    o.set("window", e.window)
        .set("requests", e.requests)
        .set("gb_seconds", e.gb_seconds)
        .set("request_charges", e.request_charges)
        .set("runtime_charges", e.runtime_charges)
        .set("developer_total", e.developer_total())
        .set("provider_infra_cost", e.provider_infra_cost);
    o
}

/// The historical `cost` subcommand report: per-window / per-30-days
/// pricing table plus a one-line simulation summary.
fn render_cost(results: &SimResults, block: &CostBlock) -> String {
    let est = &block.estimate;
    // With no explicit scale window the CLI always reported 30 days.
    let month_owned;
    let month = match &block.scaled {
        Some(m) => m,
        None => {
            month_owned = scale_to(est, 30.0 * 86_400.0);
            &month_owned
        }
    };
    let mut t = Table::new(vec!["item", "per window", "per 30 days"]);
    t.row(vec![
        "requests".to_string(),
        format!("{:.0}", est.requests),
        format!("{:.0}", month.requests),
    ]);
    t.row(vec![
        "GB-seconds".to_string(),
        format!("{:.1}", est.gb_seconds),
        format!("{:.1}", month.gb_seconds),
    ]);
    t.row(vec![
        "request charges".to_string(),
        format!("${:.4}", est.request_charges),
        format!("${:.2}", month.request_charges),
    ]);
    t.row(vec![
        "runtime charges".to_string(),
        format!("${:.4}", est.runtime_charges),
        format!("${:.2}", month.runtime_charges),
    ]);
    t.row(vec![
        "developer total".to_string(),
        format!("${:.4}", est.developer_total()),
        format!("${:.2}", month.developer_total()),
    ]);
    t.row(vec![
        "provider infra cost".to_string(),
        format!("${:.4}", est.provider_infra_cost),
        format!("${:.2}", month.provider_infra_cost),
    ]);
    let mut s = t.render();
    s.push_str(&format!(
        "cold start prob {:.4}% | avg servers {:.3} | wasted {:.1}%\n",
        results.cold_start_prob * 100.0,
        results.avg_server_count,
        results.wasted_capacity * 100.0
    ));
    s
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::scenario::spec::{FleetScenario, KeepAliveSpec, ProcessSpec};
    use crate::sim::SimConfig;

    fn assert_results_bit_identical(a: &SimResults, b: &SimResults) {
        assert_eq!(a.total_requests, b.total_requests);
        assert_eq!(a.cold_requests, b.cold_requests);
        assert_eq!(a.warm_requests, b.warm_requests);
        assert_eq!(a.rejected_requests, b.rejected_requests);
        assert_eq!(a.cold_start_prob.to_bits(), b.cold_start_prob.to_bits());
        assert_eq!(a.avg_server_count.to_bits(), b.avg_server_count.to_bits());
        assert_eq!(a.avg_response_time.to_bits(), b.avg_response_time.to_bits());
        assert_eq!(
            a.billed_instance_seconds.to_bits(),
            b.billed_instance_seconds.to_bits()
        );
        assert_eq!(a.wasted_capacity.to_bits(), b.wasted_capacity.to_bits());
    }

    /// `run_scenario(steady)` == hand-built `ServerlessSimulator` (the old
    /// `steady` subcommand body), bit for bit.
    #[test]
    fn steady_matches_direct_simulator() {
        let spec = ScenarioSpec::new("t").with_horizon(20_000.0).with_seed(1);
        let report = run_scenario(&spec).unwrap();
        let direct = {
            let mut cfg = SimConfig::table1();
            cfg.horizon = 20_000.0;
            cfg.seed = 1;
            ServerlessSimulator::new(cfg).run()
        };
        match report {
            ScenarioReport::Steady { results, cost, telemetry } => {
                assert!(cost.is_none());
                assert!(telemetry.is_none());
                assert_results_bit_identical(&results, &direct);
            }
            _ => panic!("wrong report kind"),
        }
    }

    /// `run_scenario(temporal)` == the old `temporal` subcommand body.
    #[test]
    fn temporal_matches_direct_engine() {
        let spec = ScenarioSpec::new("t")
            .with_horizon(3_000.0)
            .with_experiment(ExperimentSpec::Temporal {
                replications: 4,
                sample_interval: Some(100.0),
                warm_pool: 2,
            });
        let report = run_scenario(&spec).unwrap();
        let direct = {
            let mut cfg = SimConfig::table1();
            cfg.horizon = 3_000.0;
            cfg.sample_interval = 100.0;
            ServerlessTemporalSimulator::new(cfg, InitialState::warm_pool(2), 4).run()
        };
        match report {
            ScenarioReport::Temporal { results, .. } => {
                assert_eq!(results.runs.len(), direct.runs.len());
                for (a, b) in results.runs.iter().zip(&direct.runs) {
                    assert_results_bit_identical(a, b);
                }
                assert_eq!(
                    results.avg_server_count_ci.0.to_bits(),
                    direct.avg_server_count_ci.0.to_bits()
                );
            }
            _ => panic!("wrong report kind"),
        }
    }

    /// `run_scenario(ensemble)` == `run_ensemble` / the what-if grid.
    #[test]
    fn ensemble_matches_direct_engine() {
        let base = ScenarioSpec::new("t").with_horizon(4_000.0).with_seed(7);
        let spec = base.clone().with_experiment(ExperimentSpec::Ensemble {
            replications: 3,
            threads: 2,
            thresholds: vec![],
        });
        let direct = {
            let mut cfg = SimConfig::table1();
            cfg.horizon = 4_000.0;
            cfg.seed = 7;
            run_ensemble(&cfg, &EnsembleOpts { replications: 3, threads: 2, root_seed: 7 })
        };
        match run_scenario(&spec).unwrap() {
            ScenarioReport::EnsembleSingle { results } => {
                assert_eq!(results.seeds, direct.seeds);
                for (a, b) in results.runs.iter().zip(&direct.runs) {
                    assert_results_bit_identical(a, b);
                }
            }
            _ => panic!("wrong report kind"),
        }

        let spec = base.with_experiment(ExperimentSpec::Ensemble {
            replications: 3,
            threads: 2,
            thresholds: vec![120.0, 600.0],
        });
        let direct_grid = {
            let mut cfg = SimConfig::table1();
            cfg.horizon = 4_000.0;
            cfg.seed = 7;
            whatif::expiration_threshold_ensemble(
                &cfg,
                &[120.0, 600.0],
                &EnsembleOpts { replications: 3, threads: 2, root_seed: 7 },
            )
        };
        match run_scenario(&spec).unwrap() {
            ScenarioReport::EnsembleGrid { grid, .. } => {
                assert_eq!(grid.len(), direct_grid.len());
                for ((tha, ra), (thb, rb)) in grid.iter().zip(&direct_grid) {
                    assert_eq!(tha, thb);
                    for (a, b) in ra.runs.iter().zip(&rb.runs) {
                        assert_results_bit_identical(a, b);
                    }
                }
            }
            _ => panic!("wrong report kind"),
        }
    }

    /// `run_scenario(sweep)` on the default platform == `figures::fig5_sweep`
    /// (the old `sweep` subcommand body).
    #[test]
    fn sweep_matches_fig5() {
        let rates = vec![0.5, 1.0];
        let thresholds = vec![300.0, 600.0];
        let spec = ScenarioSpec::new("t")
            .with_horizon(8_000.0)
            .with_seed(0x5EED)
            .with_experiment(ExperimentSpec::Sweep {
                rates: rates.clone(),
                thresholds: thresholds.clone(),
            });
        let direct = figures::fig5_sweep(&rates, &thresholds, 8_000.0, 0x5EED);
        match run_scenario(&spec).unwrap() {
            ScenarioReport::Sweep { series, .. } => {
                assert_eq!(series.len(), direct.len());
                for ((tha, sa), (thb, sb)) in series.iter().zip(&direct) {
                    assert_eq!(tha, thb);
                    for (&(ra, pa), &(rb, pb)) in sa.iter().zip(sb) {
                        assert_eq!(ra.to_bits(), rb.to_bits());
                        assert_eq!(pa.to_bits(), pb.to_bits());
                    }
                }
            }
            _ => panic!("wrong report kind"),
        }
    }

    /// `run_scenario(compare)` == `analytical::compare_steady_state` (the
    /// old `compare` subcommand body).
    #[test]
    fn compare_matches_direct_baseline() {
        let spec = ScenarioSpec::new("t")
            .with_horizon(10_000.0)
            .with_expiration_threshold(120.0)
            .with_experiment(ExperimentSpec::Compare {
                service_mean: 2.0,
                markovian_expiration: true,
            });
        let direct = {
            let mut cfg = SimConfig::table1();
            cfg.horizon = 10_000.0;
            cfg.expiration_threshold = 120.0;
            cfg.cold_service = Process::exp_mean(2.0);
            cfg.warm_service = Process::exp_mean(2.0);
            analytical::compare_steady_state_markovian(&cfg, 2.0)
        };
        match run_scenario(&spec).unwrap() {
            ScenarioReport::Compare { report } => {
                assert_eq!(report.rows.len(), direct.rows.len());
                for (a, b) in report.rows.iter().zip(&direct.rows) {
                    assert_eq!(a.name, b.name);
                    assert_eq!(a.analytical.to_bits(), b.analytical.to_bits());
                    assert_eq!(a.simulated.to_bits(), b.simulated.to_bits());
                }
            }
            _ => panic!("wrong report kind"),
        }
    }

    /// `run_scenario(fleet)` == the old `fleet` subcommand body: same trace
    /// generation, same fleet config, same cost pass.
    #[test]
    fn fleet_matches_direct_engine() {
        let spec = ScenarioSpec::new("t")
            .with_horizon(1_500.0)
            .with_skip_initial(0.0)
            .with_seed(3)
            .with_experiment(ExperimentSpec::Fleet(
                FleetScenario::new(5).with_threads(2),
            ));
        let direct = {
            let mut rng = Rng::new(3);
            let trace = SyntheticTrace::generate(5, &mut rng);
            let mut cfg = FleetConfig::from_trace(
                &trace,
                1_500.0,
                0.0,
                3,
                crate::fleet::PolicySpec::fixed(600.0),
            );
            cfg.threads = 2;
            let results = cfg.run();
            let cost =
                fleet_cost(&cfg, &results, &PricingTable::aws_lambda());
            (results, cost)
        };
        match run_scenario(&spec).unwrap() {
            ScenarioReport::Fleet { results, cost, .. } => {
                assert_eq!(results.names, direct.0.names);
                for (a, b) in results.per_function.iter().zip(&direct.0.per_function) {
                    assert_results_bit_identical(a, b);
                }
                assert_eq!(
                    cost.total.developer_total().to_bits(),
                    direct.1.total.developer_total().to_bits()
                );
            }
            _ => panic!("wrong report kind"),
        }
    }

    /// A spec listing only `compare_extra` policies (no fixed-threshold
    /// grid) still enters comparison mode rather than silently running
    /// the primary policy alone.
    #[test]
    fn fleet_compare_extra_alone_triggers_comparison() {
        let spec = ScenarioSpec::new("t")
            .with_horizon(600.0)
            .with_skip_initial(0.0)
            .with_experiment(ExperimentSpec::Fleet(FleetScenario::new(2).with_comparison(
                vec![],
                vec![KeepAliveSpec::hybrid_histogram(3_600.0, 60.0)],
            )));
        match run_scenario(&spec).unwrap() {
            ScenarioReport::FleetComparison { outcomes, .. } => {
                assert_eq!(outcomes.len(), 1);
                assert!(outcomes[0].label.contains("hybrid-histogram"));
            }
            _ => panic!("expected comparison mode"),
        }
    }

    /// Fleet policy comparison routes through the same what-if sweep.
    #[test]
    fn fleet_comparison_matches_whatif() {
        let spec = ScenarioSpec::new("t")
            .with_horizon(1_200.0)
            .with_skip_initial(0.0)
            .with_seed(9)
            .with_experiment(ExperimentSpec::Fleet(
                FleetScenario::new(4).with_comparison(
                    vec![60.0, 600.0],
                    vec![KeepAliveSpec::hybrid_histogram(3_600.0, 60.0)],
                ),
            ));
        match run_scenario(&spec).unwrap() {
            ScenarioReport::FleetComparison { outcomes, functions, .. } => {
                assert_eq!(functions, 4);
                assert_eq!(outcomes.len(), 3);
                assert!(outcomes[0].label.contains("fixed(60s)"));
                assert!(outcomes[2].label.contains("hybrid-histogram"));
                // Same mix under every policy: arrivals are policy-invariant.
                let totals: Vec<u64> =
                    outcomes.iter().map(|o| o.results.aggregate.total_requests).collect();
                assert_eq!(totals[0], totals[1]);
                assert_eq!(totals[0], totals[2]);
            }
            _ => panic!("wrong report kind"),
        }
    }

    /// The cost axis reproduces the old `cost` subcommand numbers.
    #[test]
    fn cost_axis_matches_direct_estimate() {
        let spec = ScenarioSpec::new("t")
            .with_horizon(20_000.0)
            .with_cost(CostSpec::monthly(crate::cost::Provider::AzureFunctions, 256.0));
        let direct = {
            let mut cfg = SimConfig::table1();
            cfg.horizon = 20_000.0;
            let results = ServerlessSimulator::new(cfg).run();
            let est = estimate(
                &results,
                &FunctionConfig::new(256.0),
                &PricingTable::azure_functions(),
            );
            (scale_to(&est, 30.0 * 86_400.0), est)
        };
        match run_scenario(&spec).unwrap() {
            ScenarioReport::Steady { cost: Some(block), .. } => {
                assert_eq!(
                    block.estimate.gb_seconds.to_bits(),
                    direct.1.gb_seconds.to_bits()
                );
                assert_eq!(
                    block.estimate.developer_total().to_bits(),
                    direct.1.developer_total().to_bits()
                );
                let scaled = block.scaled.expect("monthly window");
                assert_eq!(
                    scaled.runtime_charges.to_bits(),
                    direct.0.runtime_charges.to_bits()
                );
            }
            _ => panic!("wrong report kind"),
        }
    }

    /// Spec → JSON → parse → run is bit-identical to spec → run.
    #[test]
    fn json_roundtrip_runs_bit_identical() {
        let spec = ScenarioSpec::new("rt")
            .with_arrival(ProcessSpec::Mmpp { rates: [1.5, 0.3], switch: [0.02, 0.05] })
            .with_services(
                ProcessSpec::Gaussian { mean: 2.0, std: 0.4 },
                ProcessSpec::ExpMean(2.5),
            )
            .with_horizon(6_000.0)
            .with_seed(42);
        let reparsed = ScenarioSpec::from_json_str(&spec.to_json_string()).unwrap();
        assert_eq!(reparsed, spec);
        let a = run_scenario(&spec).unwrap();
        let b = run_scenario(&reparsed).unwrap();
        match (a, b) {
            (
                ScenarioReport::Steady { results: ra, .. },
                ScenarioReport::Steady { results: rb, .. },
            ) => assert_results_bit_identical(&ra, &rb),
            _ => panic!("wrong report kinds"),
        }
    }

    #[test]
    fn render_and_json_cover_every_kind() {
        let specs = vec![
            ScenarioSpec::new("steady").with_horizon(2_000.0),
            ScenarioSpec::new("cost")
                .with_horizon(2_000.0)
                .with_cost(CostSpec::default()),
            ScenarioSpec::new("temporal")
                .with_horizon(1_000.0)
                .with_experiment(ExperimentSpec::Temporal {
                    replications: 2,
                    sample_interval: Some(100.0),
                    warm_pool: 0,
                }),
            ScenarioSpec::new("ens")
                .with_horizon(1_000.0)
                .with_experiment(ExperimentSpec::ensemble(2)),
            ScenarioSpec::new("grid").with_horizon(1_000.0).with_experiment(
                ExperimentSpec::Ensemble {
                    replications: 2,
                    threads: 1,
                    thresholds: vec![120.0, 600.0],
                },
            ),
            ScenarioSpec::new("sweep").with_horizon(1_000.0).with_experiment(
                ExperimentSpec::Sweep { rates: vec![0.5, 1.0], thresholds: vec![600.0] },
            ),
            ScenarioSpec::new("cmp")
                .with_horizon(5_000.0)
                .with_experiment(ExperimentSpec::Compare {
                    service_mean: 2.0,
                    markovian_expiration: false,
                }),
            ScenarioSpec::new("fleet")
                .with_horizon(800.0)
                .with_skip_initial(0.0)
                .with_experiment(ExperimentSpec::Fleet(FleetScenario::new(3))),
            ScenarioSpec::new("fleetcmp")
                .with_horizon(800.0)
                .with_skip_initial(0.0)
                .with_experiment(ExperimentSpec::Fleet(
                    FleetScenario::new(3)
                        .with_comparison(vec![120.0], vec![]),
                )),
        ];
        for spec in specs {
            let report = run_scenario(&spec).unwrap();
            let text = report.render(&spec);
            assert!(!text.is_empty(), "{} rendered empty", spec.name);
            assert!(text.ends_with('\n'), "{} render lacks trailing newline", spec.name);
            let json = report.to_json(&spec).to_string();
            assert!(json.starts_with('{'), "{}: {json}", spec.name);
            // Report JSON is parseable by our own reader.
            JsonValue::parse(&json).unwrap();
            // And the formatted runner honours the output axis.
            let line = run_scenario_to_string(
                &spec.clone().with_output(OutputFormat::Json),
            )
            .unwrap();
            assert!(line.starts_with('{') && line.ends_with("}\n"), "{line}");
        }
    }

    /// The reliability axis reaches both engines: faults surface in the
    /// steady results and the fleet aggregate, and a disabled axis is
    /// bit-identical to no axis at all.
    #[test]
    fn reliability_axis_reaches_steady_and_fleet_engines() {
        use crate::scenario::spec::ReliabilitySpec;
        use crate::sim::fault::FaultProfile;
        use crate::sim::retry::RetryPolicy;
        let rel = ReliabilitySpec::new(
            FaultProfile::disabled().with_failure_prob(0.2),
            RetryPolicy::exponential(0.05, 2.0, 3),
        );
        let steady = ScenarioSpec::new("s")
            .with_horizon(5_000.0)
            .with_seed(11)
            .with_reliability(rel.clone());
        match run_scenario(&steady).unwrap() {
            ScenarioReport::Steady { results, .. } => {
                assert!(results.failed_requests > 0);
                assert!(results.retry_attempts > 0);
            }
            _ => panic!("wrong report kind"),
        }
        let fleet = ScenarioSpec::new("f")
            .with_horizon(1_500.0)
            .with_skip_initial(0.0)
            .with_seed(3)
            .with_experiment(ExperimentSpec::Fleet(FleetScenario::new(4)))
            .with_reliability(rel);
        match run_scenario(&fleet).unwrap() {
            ScenarioReport::Fleet { results, .. } => {
                assert!(results.aggregate.failed_requests > 0);
                assert!(results.aggregate.retry_attempts > 0);
            }
            _ => panic!("wrong report kind"),
        }
        let plain = ScenarioSpec::new("p").with_horizon(5_000.0).with_seed(11);
        let noop = plain.clone().with_reliability(ReliabilitySpec::default());
        match (run_scenario(&plain).unwrap(), run_scenario(&noop).unwrap()) {
            (
                ScenarioReport::Steady { results: a, .. },
                ScenarioReport::Steady { results: b, .. },
            ) => {
                assert_results_bit_identical(&a, &b);
                assert_eq!(a.failed_requests, 0);
            }
            _ => panic!("wrong report kinds"),
        }
    }

    /// The observability axis records spans/samples on both engines,
    /// writes the three export files, and never perturbs the simulation
    /// results (telemetry draws no RNG and schedules no events).
    #[test]
    fn observability_axis_records_and_exports() {
        let dir =
            std::env::temp_dir().join(format!("simfaas_run_telemetry_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let trace_path = dir.join("steady.jsonl").display().to_string();
        let plain = ScenarioSpec::new("p").with_horizon(3_000.0).with_seed(5);
        let observed = plain
            .clone()
            .with_observability(ObservabilitySpec::new(Some(trace_path.clone()), 60.0));
        let (a, b) = (run_scenario(&plain).unwrap(), run_scenario(&observed).unwrap());
        match (&a, &b) {
            (
                ScenarioReport::Steady { results: ra, telemetry: None, .. },
                ScenarioReport::Steady { results: rb, telemetry: Some(t), .. },
            ) => {
                assert_results_bit_identical(ra, rb);
                assert_eq!(t.spans as u64, rb.total_requests);
                assert!(t.samples > 0);
                let doc = JsonValue::parse(
                    &std::fs::read_to_string(t.perfetto_path.as_ref().unwrap()).unwrap(),
                )
                .unwrap();
                assert!(doc.get("traceEvents").is_some());
                let metrics =
                    std::fs::read_to_string(t.metrics_path.as_ref().unwrap()).unwrap();
                assert!(metrics.starts_with("function,t,"), "{metrics}");
                let spans = crate::telemetry::read_spans_jsonl(
                    std::fs::read_to_string(&trace_path).unwrap().as_bytes(),
                )
                .unwrap();
                assert_eq!(spans.len(), t.spans);
            }
            _ => panic!("wrong report kinds"),
        }
        // The summary reaches both output formats.
        let text = b.render(&observed);
        assert!(text.contains("telemetry:"), "{text}");
        let json = b.to_json(&observed).to_string();
        assert!(json.contains("\"telemetry\":"), "{json}");
        // Fleet, interval-only: counts flow through FleetResults, no files.
        let fleet = ScenarioSpec::new("f")
            .with_horizon(800.0)
            .with_skip_initial(0.0)
            .with_experiment(ExperimentSpec::Fleet(FleetScenario::new(3)))
            .with_observability(ObservabilitySpec::new(None, 120.0));
        match run_scenario(&fleet).unwrap() {
            ScenarioReport::Fleet { results, telemetry: Some(t), .. } => {
                assert_eq!(t.spans as u64, results.aggregate.total_requests);
                assert!(t.samples > 0);
                assert!(t.span_path.is_none());
            }
            _ => panic!("wrong report kind"),
        }
        std::fs::remove_dir_all(&dir).ok();
    }

    /// A controlled fleet scenario carries its §Control digest through
    /// every output surface: the report struct, the rendered table, the
    /// JSON, and (with a record_trace path) the control-tick CSV.
    #[test]
    fn controlled_fleet_reports_and_exports_control_ticks() {
        use crate::control::ControllerSpec;
        let dir =
            std::env::temp_dir().join(format!("simfaas_run_control_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let trace_path = dir.join("ctl.jsonl").display().to_string();
        let spec = ScenarioSpec::new("ctl")
            .with_horizon(2_000.0)
            .with_skip_initial(0.0)
            .with_seed(4)
            .with_experiment(ExperimentSpec::Fleet(
                FleetScenario::new(6).with_fleet_cap(3).with_controller(
                    ControllerSpec::target_tracking(0.7).with_tick(50.0).with_bounds(1, 16),
                ),
            ))
            .with_observability(ObservabilitySpec::new(Some(trace_path), 500.0));
        let report = run_scenario(&spec).unwrap();
        match &report {
            ScenarioReport::Fleet { results, telemetry, .. } => {
                let ctl = results.control.as_ref().expect("controlled run reports control");
                assert!(ctl.ticks > 0);
                let csv_path = telemetry
                    .as_ref()
                    .and_then(|t| t.control_path.clone())
                    .expect("record_trace writes the control CSV");
                let csv = std::fs::read_to_string(&csv_path).unwrap();
                assert!(csv.starts_with("domain,t,observed,"), "{csv}");
                assert_eq!(csv.lines().count(), ctl.samples.len() + 1);
            }
            _ => panic!("wrong report kind"),
        }
        let text = report.render(&spec);
        assert!(text.contains("Controller target:0.7"), "{text}");
        assert!(text.contains("control ticks:"), "{text}");
        let json = report.to_json(&spec).to_string();
        assert!(json.contains("\"control\":"), "{json}");
        assert!(json.contains("\"settling_time\":"), "{json}");
        std::fs::remove_dir_all(&dir).ok();
    }

    /// A drain window that outlives the horizon is flagged in the rendered
    /// report (satellite: the cordoned host silently leaks capacity).
    #[test]
    fn unfinished_drain_window_warns_in_the_report() {
        use crate::cluster::ClusterConfig;
        let spec = ScenarioSpec::new("leak")
            .with_horizon(1_000.0)
            .with_skip_initial(0.0)
            .with_experiment(ExperimentSpec::Fleet(FleetScenario::new(3).with_cluster(
                ClusterConfig::new(2, 2_048.0, 16.0).with_drain(1, 500.0, 5_000.0),
            )));
        let report = run_scenario(&spec).unwrap();
        let text = report.render(&spec);
        assert!(text.contains("never completes within the 1000 s horizon"), "{text}");
        // A drain that finishes in time stays quiet.
        let ok = ScenarioSpec::new("ok")
            .with_horizon(1_000.0)
            .with_skip_initial(0.0)
            .with_experiment(ExperimentSpec::Fleet(FleetScenario::new(3).with_cluster(
                ClusterConfig::new(2, 2_048.0, 16.0).with_drain(1, 100.0, 400.0),
            )));
        let report = run_scenario(&ok).unwrap();
        assert!(!report.render(&ok).contains("warning:"));
    }

    #[test]
    fn invalid_spec_is_rejected_before_running() {
        let spec = ScenarioSpec::new("bad").with_experiment(ExperimentSpec::ensemble(0));
        let err = run_scenario(&spec).unwrap_err().to_string();
        assert!(err.contains("replications"), "{err}");
    }
}
