//! Transient analysis (paper §4.2, Fig. 4): time-bounded metrics from custom
//! initial states, with replications and confidence intervals — the
//! capability the Markovian models of prior work could only offer for
//! exponential processes.
//!
//! Run with: `cargo run --release --example transient_analysis`

use simfaas::output::{ascii_lines, Series};
use simfaas::sim::{InitialState, ServerlessTemporalSimulator, SimConfig};

fn main() {
    let mut cfg = SimConfig::table1();
    cfg.horizon = 30_000.0;
    cfg.sample_interval = 150.0;

    println!("== Fig 4: average instance count over time (10 runs, 95% CI) ==\n");
    let res = ServerlessTemporalSimulator::new(cfg.clone(), InitialState::empty(), 10).run();
    let band = res.average_count_band();
    let series = vec![
        Series::new("mean", band.iter().map(|&(t, m, _)| (t, m)).collect()),
        Series::new("mean+ci", band.iter().map(|&(t, m, h)| (t, m + h)).collect()),
        Series::new("mean-ci", band.iter().map(|&(t, m, h)| (t, m - h)).collect()),
    ];
    print!("{}", ascii_lines(&series, 72, 16));
    let last = band.last().unwrap();
    println!(
        "final estimate {:.4} ± {:.4} ({:.2}% of mean; paper reports <1%)\n",
        last.1,
        last.2,
        100.0 * last.2 / last.1
    );

    println!("== cold vs pre-warmed start (time-bounded QoS guarantees) ==\n");
    // An operator pre-warms 10 instances before a product launch: what is
    // the cold-start exposure over the first 10 minutes?
    let mut short = cfg;
    short.horizon = 600.0;
    short.sample_interval = 10.0;
    for (label, init) in [
        ("empty platform", InitialState::empty()),
        ("pre-warmed pool of 10", InitialState::warm_pool(10)),
    ] {
        let r = ServerlessTemporalSimulator::new(short.clone(), init, 20).run();
        let (p, hw) = r.cold_start_prob_ci;
        println!(
            "  {label:<24} P(cold over first 10 min) = {:.3}% ± {:.3}%",
            p * 100.0,
            hw * 100.0
        );
    }
}
