//! Streaming (lazy) arrival generation — the `ArrivalSource` seam.
//!
//! Historically every trace-driven run materialized its full arrival
//! vector up front (`Vec<f64>` per function), so a fleet run cost
//! O(total-invocations) resident memory before the first event fired.
//! This module replaces that with demand-driven generation:
//!
//! * [`StreamingArrivals`] is the lazy twin of
//!   [`super::generator::nonhomogeneous`]: the identical Lewis & Shedler
//!   thinning draws from the identical RNG stream, but one accepted
//!   arrival per [`Iterator::next`] call and O(1) resident state — so a
//!   run driven by a [`StreamSpec`] is **bit-identical** to one replaying
//!   the eagerly materialized vector (regression-tested here and in
//!   `tests/trace_ingestion.rs`).
//! * [`ArrivalSource`] is the one runtime seam every engine pulls its next
//!   arrival from — the scale-per-request simulator, the concurrency-value
//!   simulator and the fleet engines all schedule arrivals through
//!   [`crate::sim::core::EngineCore::schedule_next_arrival`], which takes
//!   this type.

use crate::sim::process::Process;
use crate::sim::rng::Rng;
use crate::sim::time::SimTime;
use std::sync::Arc;

/// Seconds per day (the period of every daily rate profile).
pub const SECONDS_PER_DAY: f64 = 86_400.0;

/// A time-varying arrival-rate profile `rate(t)` in req/s.
#[derive(Debug, Clone)]
pub enum RateShape {
    /// Sinusoidal diurnal modulation:
    /// `mean * (1 + depth * sin(2π (t + peak_offset) / day))` — the exact
    /// expression [`super::azure::SyntheticTrace`] uses, kept verbatim so
    /// streaming generation reproduces the eager path bit-for-bit.
    Sinusoid {
        /// Mean rate (req/s) averaged over a day.
        mean: f64,
        /// Modulation depth in `[0, 1)`.
        depth: f64,
        /// Phase offset of the daily peak, seconds.
        peak_offset: f64,
    },
    /// Piecewise-constant per-bin rates repeating with period
    /// `rates.len() * bin_secs` — the shape of an ingested Azure
    /// invocations-per-minute row (`bin_secs = 60`).
    PiecewiseDaily {
        /// Rate (req/s) per bin.
        rates: Arc<Vec<f64>>,
        /// Bin width in seconds.
        bin_secs: f64,
    },
}

impl RateShape {
    /// Instantaneous rate at absolute time `t` seconds.
    pub fn eval(&self, t: f64) -> f64 {
        match self {
            RateShape::Sinusoid { mean, depth, peak_offset } => {
                mean * (1.0
                    + depth
                        * (2.0 * std::f64::consts::PI * (t + peak_offset) / SECONDS_PER_DAY)
                            .sin())
            }
            RateShape::PiecewiseDaily { rates, bin_secs } => {
                if rates.is_empty() {
                    return 0.0;
                }
                let period = rates.len() as f64 * bin_secs;
                let tm = t % period;
                let idx = ((tm / bin_secs) as usize).min(rates.len() - 1);
                rates[idx]
            }
        }
    }

    /// A bound on `rate(t)` over all `t` (the thinning envelope).
    pub fn max_rate(&self) -> f64 {
        match self {
            RateShape::Sinusoid { mean, depth, .. } => mean * (1.0 + depth),
            RateShape::PiecewiseDaily { rates, .. } => {
                rates.iter().copied().fold(0.0, f64::max)
            }
        }
    }

    /// Long-run mean rate (req/s), averaged over one period.
    pub fn mean_rate(&self) -> f64 {
        match self {
            RateShape::Sinusoid { mean, .. } => *mean,
            RateShape::PiecewiseDaily { rates, .. } => {
                if rates.is_empty() {
                    0.0
                } else {
                    rates.iter().sum::<f64>() / rates.len() as f64
                }
            }
        }
    }
}

/// Specification of a streaming arrival generator — the cloneable, RNG-free
/// half of [`StreamingArrivals`]. Held by
/// [`super::source::ArrivalMode::Streaming`]; the engine builds the runtime
/// generator per run, so repeated runs (policy sweeps, what-if grids)
/// replay identical arrivals without retaining any of them.
#[derive(Debug, Clone)]
pub struct StreamSpec {
    /// The rate profile.
    pub shape: RateShape,
    /// Thinning envelope (must bound `shape` everywhere).
    pub rate_max: f64,
    /// Seed of the generator's dedicated RNG stream (one stream per
    /// function, disjoint from the engine's service-draw stream).
    pub seed: u64,
}

impl StreamSpec {
    /// Sinusoidal diurnal profile (the synthetic-trace shape).
    pub fn sinusoid(mean: f64, depth: f64, peak_offset: f64, seed: u64) -> StreamSpec {
        let shape = RateShape::Sinusoid { mean, depth, peak_offset };
        let rate_max = shape.max_rate();
        StreamSpec { shape, rate_max, seed }
    }

    /// Piecewise-constant daily profile (the ingested-dataset shape).
    pub fn piecewise_daily(rates: Arc<Vec<f64>>, bin_secs: f64, seed: u64) -> StreamSpec {
        let shape = RateShape::PiecewiseDaily { rates, bin_secs };
        let rate_max = shape.max_rate();
        StreamSpec { shape, rate_max, seed }
    }

    /// Build the runtime generator, emitting arrivals in `[0, stop_at)`.
    pub fn build(&self, stop_at: f64) -> StreamingArrivals {
        StreamingArrivals::new(self.shape.clone(), self.rate_max, self.seed, stop_at)
    }
}

/// Lazy non-homogeneous Poisson arrivals via thinning (Lewis & Shedler).
///
/// Draw-for-draw identical to [`super::generator::nonhomogeneous`] on the
/// same seed — it performs the same `exponential(rate_max)` candidate and
/// `uniform()` acceptance draws in the same order — but yields one accepted
/// arrival per `next()` call instead of materializing the whole horizon.
#[derive(Debug, Clone)]
pub struct StreamingArrivals {
    rng: Rng,
    shape: RateShape,
    rate_max: f64,
    t: f64,
    stop_at: f64,
    done: bool,
}

impl StreamingArrivals {
    /// Generator over `[0, stop_at)`. A non-positive `rate_max` yields an
    /// empty stream (the eager generator asserted instead).
    pub fn new(shape: RateShape, rate_max: f64, seed: u64, stop_at: f64) -> StreamingArrivals {
        StreamingArrivals {
            rng: Rng::new(seed),
            shape,
            rate_max,
            t: 0.0,
            stop_at,
            done: rate_max <= 0.0,
        }
    }
}

impl Iterator for StreamingArrivals {
    type Item = f64;

    fn next(&mut self) -> Option<f64> {
        if self.done {
            return None;
        }
        loop {
            self.t += self.rng.exponential(self.rate_max);
            if self.t >= self.stop_at {
                self.done = true;
                return None;
            }
            let r = self.shape.eval(self.t);
            debug_assert!(r <= self.rate_max * (1.0 + 1e-9), "rate(t) exceeds rate_max");
            if self.rng.uniform() * self.rate_max < r {
                return Some(self.t);
            }
        }
    }
}

/// The runtime arrival seam: where an engine's next arrival comes from.
///
/// Every engine holds one of these and schedules arrivals through
/// [`crate::sim::core::EngineCore::schedule_next_arrival`]; only the
/// `Process` variant draws from the engine's RNG (preserving the
/// historical draw order: service draws first, next-arrival gap last).
pub enum ArrivalSource {
    /// Inter-arrival process drawn from the engine's RNG stream.
    Process(Process),
    /// Replay of recorded absolute arrival times (sorted ascending).
    Replay {
        /// The recorded timestamps.
        times: Arc<Vec<f64>>,
        /// Index of the next timestamp to replay.
        next: usize,
    },
    /// Streaming thinning generator with its own dedicated RNG stream.
    Stream(StreamingArrivals),
}

impl ArrivalSource {
    /// Arrivals from an inter-arrival process.
    pub fn process(p: Process) -> ArrivalSource {
        ArrivalSource::Process(p)
    }

    /// Replay of a recorded arrival vector. The times must be sorted
    /// non-decreasing — a backwards clock would silently corrupt the
    /// engines' time-weighted accumulators, so unsorted input is rejected
    /// here, in release builds too.
    pub fn replay(times: Arc<Vec<f64>>) -> anyhow::Result<ArrivalSource> {
        if let Some(i) = times.windows(2).position(|w| w[0] > w[1]) {
            anyhow::bail!(
                "recorded arrival times must be sorted non-decreasing: \
                 times[{}] = {} > times[{}] = {}",
                i,
                times[i],
                i + 1,
                times[i + 1]
            );
        }
        Ok(ArrivalSource::Replay { times, next: 0 })
    }

    /// The next absolute arrival time after `now`, or `None` when the
    /// source is exhausted. `rng` is the engine's RNG, consumed only by the
    /// `Process` variant (replay and streaming sources are self-contained).
    #[inline]
    pub fn next_after(&mut self, now: SimTime, rng: &mut Rng) -> Option<SimTime> {
        match self {
            ArrivalSource::Process(p) => Some(now.after(p.sample(rng))),
            ArrivalSource::Replay { times, next } => {
                let t = *times.get(*next)?;
                *next += 1;
                Some(SimTime::from_secs(t))
            }
            ArrivalSource::Stream(s) => s.next().map(SimTime::from_secs),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload::generator::nonhomogeneous;

    #[test]
    fn streaming_sinusoid_is_bit_identical_to_eager_thinning() {
        // The tentpole contract: the lazy generator consumes the identical
        // RNG stream as generator::nonhomogeneous, so the accepted arrival
        // times match bit for bit.
        let (mean, depth, offset) = (1.3, 0.6, 20_000.0);
        let horizon = 3.0 * SECONDS_PER_DAY;
        for seed in [1u64, 99, 0xF1EE7] {
            let mut rng = Rng::new(seed);
            let rate = move |t: f64| {
                mean * (1.0
                    + depth * (2.0 * std::f64::consts::PI * (t + offset) / SECONDS_PER_DAY).sin())
            };
            let eager = nonhomogeneous(rate, mean * (1.0 + depth), horizon, &mut rng);
            let lazy: Vec<f64> =
                StreamSpec::sinusoid(mean, depth, offset, seed).build(horizon).collect();
            assert_eq!(eager.arrivals.len(), lazy.len(), "seed {seed}");
            for (a, b) in eager.arrivals.iter().zip(&lazy) {
                assert_eq!(a.to_bits(), b.to_bits(), "seed {seed}");
            }
        }
    }

    #[test]
    fn piecewise_daily_rate_honors_bins_and_wraps() {
        let shape = RateShape::PiecewiseDaily {
            rates: Arc::new(vec![2.0, 0.0, 1.0]),
            bin_secs: 60.0,
        };
        assert_eq!(shape.eval(0.0), 2.0);
        assert_eq!(shape.eval(61.0), 0.0);
        assert_eq!(shape.eval(179.0), 1.0);
        // Wraps with period rates.len() * bin_secs = 180 s.
        assert_eq!(shape.eval(180.0), 2.0);
        assert_eq!(shape.eval(360.0 + 65.0), 0.0);
        assert_eq!(shape.max_rate(), 2.0);
        assert!((shape.mean_rate() - 1.0).abs() < 1e-12);
    }

    #[test]
    fn piecewise_stream_hits_mean_rate() {
        // 1440-bin daily profile averaging 0.5 req/s.
        let rates: Vec<f64> = (0..1440).map(|i| if i % 2 == 0 { 1.0 } else { 0.0 }).collect();
        let spec = StreamSpec::piecewise_daily(Arc::new(rates), 60.0, 7);
        let horizon = 4.0 * SECONDS_PER_DAY;
        let n = spec.build(horizon).count() as f64;
        let expected = 0.5 * horizon;
        assert!(
            (n - expected).abs() < 4.0 * expected.sqrt(),
            "n={n} expected~{expected}"
        );
    }

    #[test]
    fn zero_rate_stream_is_empty() {
        let spec = StreamSpec::piecewise_daily(Arc::new(vec![0.0, 0.0]), 60.0, 1);
        assert_eq!(spec.build(1e6).count(), 0);
    }

    #[test]
    fn process_source_matches_direct_draws_bitwise() {
        let mut rng_a = Rng::new(5);
        let mut rng_b = Rng::new(5);
        let mut src = ArrivalSource::process(Process::exp_rate(0.9));
        let p = Process::exp_rate(0.9);
        let mut now = SimTime::ZERO;
        for _ in 0..100 {
            let got = src.next_after(now, &mut rng_a).unwrap();
            let want = now.after(p.sample(&mut rng_b));
            assert_eq!(got.as_secs().to_bits(), want.as_secs().to_bits());
            now = got;
        }
    }

    #[test]
    fn replay_source_yields_each_time_once_then_exhausts() {
        let mut rng = Rng::new(1);
        let mut src = ArrivalSource::replay(Arc::new(vec![1.0, 2.5, 9.0])).unwrap();
        let mut got = Vec::new();
        while let Some(t) = src.next_after(SimTime::ZERO, &mut rng) {
            got.push(t.as_secs());
        }
        assert_eq!(got, vec![1.0, 2.5, 9.0]);
        assert!(src.next_after(SimTime::ZERO, &mut rng).is_none());
    }

    #[test]
    fn replay_rejects_unsorted_timestamps() {
        let err = ArrivalSource::replay(Arc::new(vec![1.0, 3.0, 2.0])).unwrap_err().to_string();
        assert!(err.contains("sorted non-decreasing"), "{err}");
        assert!(err.contains("times[1] = 3 > times[2] = 2"), "{err}");
        // Equal timestamps (simultaneous arrivals) stay legal.
        assert!(ArrivalSource::replay(Arc::new(vec![1.0, 1.0, 2.0])).is_ok());
        assert!(ArrivalSource::replay(Arc::new(vec![])).is_ok());
    }
}
