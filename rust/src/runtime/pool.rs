//! Multi-threaded PJRT execution: a pool of worker threads, each owning a
//! thread-bound [`Engine`] (the `xla` crate's `PjRtClient` is `Rc`-based and
//! cannot be shared). The emulator's request path submits jobs here; this is
//! the coordinator-side analogue of an async executor, with bounded
//! submission and per-job completion signaling.

use super::engine::Engine;
use super::payload::PayloadKind;
use anyhow::{Context, Result};
use std::path::PathBuf;
use std::sync::mpsc;
use std::sync::{Arc, Mutex};

enum Job {
    Payload {
        kind: PayloadKind,
        x: Vec<f32>,
        respond: mpsc::Sender<Result<Vec<f32>>>,
    },
    Histogram {
        samples: Vec<f32>,
        lo: f32,
        hi: f32,
        respond: mpsc::Sender<Result<Vec<f64>>>,
    },
    Shutdown,
}

/// A fixed pool of PJRT worker threads.
pub struct ComputePool {
    tx: mpsc::Sender<Job>,
    workers: Vec<std::thread::JoinHandle<()>>,
    n_workers: usize,
}

impl ComputePool {
    /// Spawn `n_workers` threads, each compiling the artifacts in `dir`.
    /// Fails fast if any worker cannot load the artifacts.
    pub fn new<P: Into<PathBuf>>(dir: P, n_workers: usize) -> Result<Self> {
        assert!(n_workers >= 1);
        let dir = dir.into();
        let (tx, rx) = mpsc::channel::<Job>();
        let rx = Arc::new(Mutex::new(rx));
        let (ready_tx, ready_rx) = mpsc::channel::<Result<()>>();
        let mut workers = Vec::with_capacity(n_workers);
        for _ in 0..n_workers {
            let rx = Arc::clone(&rx);
            let dir = dir.clone();
            let ready = ready_tx.clone();
            workers.push(std::thread::spawn(move || {
                let engine = match Engine::load_dir(&dir) {
                    Ok(e) => {
                        let _ = ready.send(Ok(()));
                        e
                    }
                    Err(e) => {
                        let _ = ready.send(Err(e));
                        return;
                    }
                };
                loop {
                    // Hold the lock only while receiving.
                    let job = match rx.lock().unwrap().recv() {
                        Ok(j) => j,
                        Err(_) => break,
                    };
                    match job {
                        Job::Payload { kind, x, respond } => {
                            let _ = respond.send(engine.run_payload(kind, &x));
                        }
                        Job::Histogram { samples, lo, hi, respond } => {
                            let _ = respond.send(engine.run_histogram(&samples, lo, hi));
                        }
                        Job::Shutdown => break,
                    }
                }
            }));
        }
        drop(ready_tx);
        for _ in 0..n_workers {
            ready_rx.recv().context("worker died during startup")??;
        }
        Ok(ComputePool { tx, workers, n_workers })
    }

    pub fn n_workers(&self) -> usize {
        self.n_workers
    }

    /// Execute a payload, blocking until done (call from any thread).
    pub fn run_payload(&self, kind: PayloadKind, x: Vec<f32>) -> Result<Vec<f32>> {
        let (respond, done) = mpsc::channel();
        self.tx
            .send(Job::Payload { kind, x, respond })
            .ok()
            .context("compute pool shut down")?;
        done.recv().context("worker dropped job")?
    }

    /// Execute the histogram reduction, blocking until done.
    pub fn run_histogram(&self, samples: Vec<f32>, lo: f32, hi: f32) -> Result<Vec<f64>> {
        let (respond, done) = mpsc::channel();
        self.tx
            .send(Job::Histogram { samples, lo, hi, respond })
            .ok()
            .context("compute pool shut down")?;
        done.recv().context("worker dropped job")?
    }
}

impl Drop for ComputePool {
    fn drop(&mut self) {
        for _ in &self.workers {
            let _ = self.tx.send(Job::Shutdown);
        }
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn artifacts_dir() -> PathBuf {
        PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
    }

    #[test]
    fn pool_executes_from_many_threads() {
        let pool = Arc::new(ComputePool::new(artifacts_dir(), 2).unwrap());
        let mut handles = Vec::new();
        for t in 0..4 {
            let pool = Arc::clone(&pool);
            handles.push(std::thread::spawn(move || {
                let k = PayloadKind::Small;
                let x = vec![t as f32 * 0.1; k.input_len()];
                let out = pool.run_payload(k, x).unwrap();
                assert_eq!(out.len(), k.output_len());
                out
            }));
        }
        let outs: Vec<Vec<f32>> = handles.into_iter().map(|h| h.join().unwrap()).collect();
        // Different inputs -> different outputs; same input -> identical.
        assert_ne!(outs[1], outs[2]);
    }

    #[test]
    fn pool_histogram_counts() {
        let pool = ComputePool::new(artifacts_dir(), 1).unwrap();
        let samples: Vec<f32> = (0..1000).map(|i| i as f32 / 1000.0).collect();
        let counts = pool.run_histogram(samples, 0.0, 1.0).unwrap();
        let total: f64 = counts.iter().sum();
        assert_eq!(total, 1000.0);
    }
}
