//! Retry policies: the "what the client does about it" half of the
//! reliability layer (see DESIGN.md §Reliability).
//!
//! A [`RetryPolicy`] describes how failed / timed-out requests re-enter
//! the platform: no retry, fixed-delay, or exponential backoff with
//! decorrelated jitter (the AWS-architecture-blog variant: each delay is
//! drawn uniformly from `[base, 3 * previous_delay]` and capped), plus a
//! max-attempts ceiling and an optional run-wide retry budget. Jitter
//! draws come from the engine's dedicated fault RNG lane, never from the
//! arrival/service streams; `Backoff::None` and `Backoff::Fixed` draw
//! nothing at all.
//!
//! Re-enqueued retries flow through the engines as
//! [`crate::sim::Event::RetryArrival`] events, carrying the attempt number
//! and the previous delay (the decorrelated-jitter state) in the event
//! payload so the policy itself stays stateless.

use crate::sim::rng::Rng;
use anyhow::{bail, Context, Result};

/// Backoff shape for retry delays.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum Backoff {
    /// Immediate re-dispatch (delay 0, no RNG draw).
    None,
    /// Constant delay between attempts (no RNG draw).
    Fixed {
        /// Delay in seconds before each retry.
        delay: f64,
    },
    /// Exponential backoff with decorrelated jitter:
    /// `delay_k = min(cap, U(base, 3 * delay_{k-1}))`, `delay_0 = base`.
    Exponential {
        /// First-retry delay and the lower bound of every jitter draw.
        base: f64,
        /// Hard ceiling on any single delay, seconds.
        cap: f64,
    },
}

/// Client-side retry behaviour for failed and timed-out requests.
#[derive(Debug, Clone, PartialEq)]
pub struct RetryPolicy {
    /// How long to wait between attempts.
    pub backoff: Backoff,
    /// Total dispatch attempts per request, including the first
    /// (1 = never retry). Must be >= 1.
    pub max_attempts: u32,
    /// Optional run-wide cap on the total number of retries the platform
    /// will re-enqueue (the retry budget); once spent, further failures
    /// are final.
    pub budget: Option<u64>,
}

impl RetryPolicy {
    /// The no-retry policy (every failure is final).
    pub fn none() -> Self {
        RetryPolicy { backoff: Backoff::None, max_attempts: 1, budget: None }
    }

    /// True when this policy never re-enqueues anything.
    pub fn is_none(&self) -> bool {
        self.max_attempts <= 1
    }

    /// Fixed-delay retry: `attempts` total dispatches, `delay` seconds
    /// apart.
    pub fn fixed(delay: f64, attempts: u32) -> Self {
        RetryPolicy { backoff: Backoff::Fixed { delay }, max_attempts: attempts, budget: None }
    }

    /// Exponential backoff with decorrelated jitter.
    pub fn exponential(base: f64, cap: f64, attempts: u32) -> Self {
        RetryPolicy { backoff: Backoff::Exponential { base, cap }, max_attempts: attempts, budget: None }
    }

    /// Cap the total number of retries across the whole run.
    pub fn with_budget(mut self, budget: u64) -> Self {
        self.budget = Some(budget);
        self
    }

    /// Draw the delay before the next attempt. `prev_delay` is the delay
    /// used before the previous attempt (0 on the first retry); only the
    /// exponential variant consumes randomness.
    pub fn next_delay(&self, prev_delay: f64, rng: &mut Rng) -> f64 {
        match self.backoff {
            Backoff::None => 0.0,
            Backoff::Fixed { delay } => delay,
            Backoff::Exponential { base, cap } => {
                let prev = prev_delay.max(base);
                rng.uniform_range(base, 3.0 * prev).min(cap)
            }
        }
    }

    /// Parse a CLI-style policy string:
    /// `none` | `fixed:DELAY[,ATTEMPTS]` |
    /// `exponential:BASE,CAP[,ATTEMPTS]` (alias `exp:`).
    /// ATTEMPTS defaults to 3 when omitted.
    pub fn parse(s: &str) -> Result<RetryPolicy> {
        let s = s.trim();
        if s.eq_ignore_ascii_case("none") || s.is_empty() {
            return Ok(RetryPolicy::none());
        }
        let (kind, rest) = s
            .split_once(':')
            .with_context(|| format!("retry policy '{s}': expected none, fixed:..., or exponential:..."))?;
        let nums: Vec<f64> = rest
            .split(',')
            .map(|p| {
                p.trim()
                    .parse::<f64>()
                    .with_context(|| format!("retry policy '{s}': '{p}' is not a number"))
            })
            .collect::<Result<_>>()?;
        let policy = match kind.trim().to_ascii_lowercase().as_str() {
            "fixed" => match nums.as_slice() {
                [delay] => RetryPolicy::fixed(*delay, 3),
                [delay, attempts] => RetryPolicy::fixed(*delay, *attempts as u32),
                _ => bail!("retry policy '{s}': fixed takes DELAY[,ATTEMPTS]"),
            },
            "exponential" | "exp" => match nums.as_slice() {
                [base, cap] => RetryPolicy::exponential(*base, *cap, 3),
                [base, cap, attempts] => RetryPolicy::exponential(*base, *cap, *attempts as u32),
                _ => bail!("retry policy '{s}': exponential takes BASE,CAP[,ATTEMPTS]"),
            },
            other => bail!("retry policy '{s}': unknown kind '{other}' (none|fixed|exponential)"),
        };
        policy.validate("retry")?;
        Ok(policy)
    }

    /// Short human label for tables and sweep output.
    pub fn describe(&self) -> String {
        let head = match self.backoff {
            Backoff::None if self.is_none() => return "none".to_string(),
            Backoff::None => format!("immediate x{}", self.max_attempts),
            Backoff::Fixed { delay } => format!("fixed {delay}s x{}", self.max_attempts),
            Backoff::Exponential { base, cap } => {
                format!("exp {base}s..{cap}s x{}", self.max_attempts)
            }
        };
        match self.budget {
            Some(b) => format!("{head} (budget {b})"),
            None => head,
        }
    }

    /// Check parameters; `what` prefixes error messages.
    pub fn validate(&self, what: &str) -> Result<()> {
        if self.max_attempts == 0 {
            bail!("{what}.max_attempts must be >= 1 (1 = no retries), got 0");
        }
        match self.backoff {
            Backoff::None => {}
            Backoff::Fixed { delay } => {
                if !(delay.is_finite() && delay >= 0.0) {
                    bail!("{what}: fixed backoff delay must be finite and >= 0, got {delay}");
                }
            }
            Backoff::Exponential { base, cap } => {
                if !(base.is_finite() && base > 0.0) {
                    bail!("{what}: exponential backoff base must be positive, got {base}");
                }
                if !(cap.is_finite() && cap >= base) {
                    bail!("{what}: exponential backoff cap must be >= base ({base}), got {cap}");
                }
            }
        }
        Ok(())
    }
}

impl Default for RetryPolicy {
    fn default() -> Self {
        RetryPolicy::none()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn none_policy_is_default_and_drawless() {
        let p = RetryPolicy::default();
        assert!(p.is_none());
        assert_eq!(p.describe(), "none");
        let mut rng = Rng::new(1);
        let before = rng.next_u64();
        let mut rng2 = Rng::new(1);
        assert_eq!(p.next_delay(0.0, &mut rng2), 0.0);
        // None draws nothing: the stream is exactly one u64 behind.
        assert_eq!(rng2.next_u64(), before);
    }

    #[test]
    fn fixed_delay_is_constant_without_draws() {
        let p = RetryPolicy::fixed(2.5, 4);
        let mut rng = Rng::new(7);
        assert_eq!(p.next_delay(0.0, &mut rng), 2.5);
        assert_eq!(p.next_delay(2.5, &mut rng), 2.5);
        assert_eq!(rng.next_u64(), Rng::new(7).next_u64());
    }

    #[test]
    fn decorrelated_jitter_stays_in_band_and_caps() {
        let p = RetryPolicy::exponential(1.0, 20.0, 5);
        let mut rng = Rng::new(42);
        let mut prev = 0.0;
        for _ in 0..200 {
            let d = p.next_delay(prev, &mut rng);
            assert!(d >= 1.0 && d <= 20.0, "delay {d} out of [base, cap]");
            assert!(d <= (3.0 * prev.max(1.0)).min(20.0) + 1e-12);
            prev = d;
        }
    }

    #[test]
    fn parse_round_trips_the_cli_grammar() {
        assert!(RetryPolicy::parse("none").unwrap().is_none());
        assert_eq!(RetryPolicy::parse("fixed:2.0").unwrap(), RetryPolicy::fixed(2.0, 3));
        assert_eq!(RetryPolicy::parse("fixed:0.5,5").unwrap(), RetryPolicy::fixed(0.5, 5));
        assert_eq!(
            RetryPolicy::parse("exponential:1,60,4").unwrap(),
            RetryPolicy::exponential(1.0, 60.0, 4)
        );
        assert_eq!(RetryPolicy::parse("exp:1,60").unwrap(), RetryPolicy::exponential(1.0, 60.0, 3));
        for bad in ["bogus", "fixed:", "fixed:1,2,3", "exponential:5,1", "exp:0,10", "fixed:-1"] {
            assert!(RetryPolicy::parse(bad).is_err(), "'{bad}' should not parse");
        }
    }

    #[test]
    fn validate_rejects_zero_attempts() {
        let p = RetryPolicy { backoff: Backoff::None, max_attempts: 0, budget: None };
        let err = p.validate("reliability.retry").unwrap_err().to_string();
        assert!(err.contains("max_attempts"), "{err}");
    }
}
