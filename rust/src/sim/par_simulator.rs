//! `ParServerlessSimulator` — the paper's extensibility demonstration
//! (§3.1): serverless platforms whose instances admit **queuing / a
//! concurrency value > 1** (Google Cloud Run, Knative; paper Fig. 1) while
//! keeping the scale-per-request expiration behaviour.
//!
//! Each instance can hold up to `concurrency_value` requests at once. An
//! arrival is routed to the *newest* instance with spare capacity
//! (consistent with the paper's newest-first routing priority); if none has
//! capacity and the platform is below the maximum concurrency level, a new
//! instance cold-starts. Requests in excess of an instance's processor share
//! its capacity: with k requests in service the per-request rate is
//! unaffected up to `concurrency_value` (Cloud Run semantics — concurrent
//! slots, not processor sharing), which reduces to scale-per-request when
//! `concurrency_value == 1`.
//!
//! Since the engine unification this type is a thin configuration of
//! [`super::core::EngineCore`]: the concurrency-value router replaces the
//! idle pool, and everything else (billing at busy-period end, generation
//! -guarded expiration, O(1) level accounting) is the shared lifecycle.
//! Two historical quirks are preserved deliberately: batch arrivals and
//! the stochastic `expiration_process` are **ignored** by this engine
//! (`SimConfig` carries them for the scale-per-request simulator), exactly
//! as before the refactor.

use super::core::{ConfigExpiration, CoreParams, EngineCore};
use super::event::{CalendarEventQueue, Event};
use super::instance::FunctionInstance;
use super::results::SimResults;
use super::simulator::{expected_pending_events, SimConfig};
use super::time::SimTime;
use crate::workload::stream::ArrivalSource;

/// Scale-per-request simulator generalized with a per-instance concurrency
/// value (paper Fig. 1: one instance absorbs `c` concurrent requests).
pub struct ParServerlessSimulator {
    cfg: SimConfig,
    pub concurrency_value: u32,
    core: EngineCore,
    events: CalendarEventQueue,
    hooks: ConfigExpiration,
}

impl ParServerlessSimulator {
    pub fn new(cfg: SimConfig, concurrency_value: u32) -> Self {
        assert!(concurrency_value >= 1);
        let core = EngineCore::new(CoreParams {
            seed: cfg.seed,
            warm_service: cfg.warm_service.clone(),
            cold_service: cfg.cold_service.clone(),
            // Historical behaviour: this engine never batched arrivals.
            batch_size: None,
            max_concurrency: cfg.max_concurrency,
            skip_initial: cfg.skip_initial,
            concurrency_value,
            prewarm_lead: 0.0,
            instance_capacity: 1024,
            retain_instances: true,
            fault: cfg.fault.clone(),
            retry: cfg.retry.clone(),
        });
        // Historical behaviour: the constant threshold only (the
        // stochastic expiration_process applies to ServerlessSimulator).
        let hooks = ConfigExpiration { threshold: cfg.expiration_threshold, process: None };
        ParServerlessSimulator {
            concurrency_value,
            core,
            events: CalendarEventQueue::with_capacity(expected_pending_events(&cfg)),
            hooks,
            cfg,
        }
    }

    pub fn run(&mut self) -> SimResults {
        let horizon = SimTime::from_secs(self.cfg.horizon);
        // Arrivals pull lazily through the shared seam (first pull at
        // t = 0 draws the same first gap as the historical code).
        let mut arrival = ArrivalSource::process(self.cfg.arrival.clone());
        self.core.schedule_next_arrival(&mut self.events, &mut arrival);
        self.core.schedule_fault_timeline(&mut self.events);
        self.events.schedule(horizon, Event::Horizon);
        while let Some((t, ev)) = self.events.pop() {
            self.core.maybe_start_stats(t);
            self.core.set_now(t);
            self.core.sample_tick(None);
            match ev {
                Event::Arrival => {
                    self.core.handle_arrival(&mut self.events, &mut self.hooks);
                    self.core.schedule_next_arrival(&mut self.events, &mut arrival);
                }
                Event::Departure(id) => {
                    self.core.handle_departure(&mut self.events, &mut self.hooks, id)
                }
                Event::Expiration { id, gen } => {
                    self.core.handle_expiration(&mut self.events, &mut self.hooks, id, gen)
                }
                Event::Provision => self.core.handle_provision(&mut self.events, &mut self.hooks),
                Event::ProvisioningDone(id) => {
                    self.core.handle_provisioning_done(&mut self.events, &mut self.hooks, id)
                }
                Event::RequestTimeout(id) => {
                    self.core.handle_request_timeout(&mut self.events, &mut self.hooks, id)
                }
                Event::RetryArrival { attempt, prev_delay_bits } => self.core.handle_retry_arrival(
                    &mut self.events,
                    &mut self.hooks,
                    attempt,
                    f64::from_bits(prev_delay_bits),
                ),
                Event::DegradationStart { window } => self.core.handle_degradation_start(window),
                Event::DegradationEnd { window } => self.core.handle_degradation_end(window),
                Event::ControlTick => {
                    unreachable!("control ticks are scheduled only by the fleet run loops")
                }
                Event::Horizon => break,
            }
        }
        self.core.close(horizon);
        self.core.sample_tick(None);
        self.core.results()
    }

    /// Attach a telemetry observer (DESIGN.md §Observability). Capture
    /// draws no RNG and schedules no events, so results are unchanged.
    pub fn set_observer(&mut self, observer: crate::telemetry::Observer) {
        self.core.set_observer(observer);
    }

    /// Detach the observer (if any) and return its in-memory recording.
    pub fn take_recorder(&mut self) -> Option<crate::telemetry::TelemetryRecorder> {
        self.core.take_observer().and_then(crate::telemetry::Observer::into_recorder)
    }

    /// All instances ever created (for capacity/lifecycle assertions),
    /// materialized from the core's struct-of-arrays arena.
    pub fn instances(&self) -> Vec<FunctionInstance> {
        self.core.instances()
    }

    /// Current live/busy-instance/warm-pool counts.
    pub fn live_counts(&self) -> (usize, usize, usize) {
        self.core.live_counts()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::process::{ExpProcess, Process};
    use crate::sim::simulator::ServerlessSimulator;

    fn cfg(rate: f64, horizon: f64, seed: u64) -> SimConfig {
        SimConfig {
            arrival: Process::exp_rate(rate),
            batch_size: None,
            warm_service: Process::exp_mean(1.991),
            cold_service: Process::exp_mean(2.244),
            expiration_threshold: 600.0,
            expiration_process: None,
            max_concurrency: 1000,
            horizon,
            skip_initial: 100.0,
            seed,
            capture_request_log: false,
            sample_interval: 0.0,
            fault: crate::sim::fault::FaultProfile::disabled(),
            retry: crate::sim::retry::RetryPolicy::none(),
        }
    }

    #[test]
    fn faults_flow_through_the_concurrency_value_engine() {
        let mut c = cfg(5.0, 20_000.0, 21);
        c.fault = crate::sim::fault::FaultProfile::disabled().with_failure_prob(0.2);
        c.retry = crate::sim::retry::RetryPolicy::fixed(1.0, 2);
        let r = ParServerlessSimulator::new(c, 3).run();
        assert!(r.failed_requests > 0);
        assert!(r.retry_attempts > 0);
        let served = (r.cold_requests + r.warm_requests) as f64;
        let observed = r.failed_requests as f64 / served;
        assert!((observed - 0.2).abs() < 0.02, "observed failure rate {observed}");
        assert!(r.goodput > 0.0);
    }

    #[test]
    fn concurrency_one_matches_scale_per_request() {
        // With c=1 the generalized simulator must agree (statistically)
        // with ServerlessSimulator on the same workload.
        let r1 = ParServerlessSimulator::new(cfg(0.9, 100_000.0, 1), 1).run();
        let r2 = ServerlessSimulator::new(cfg(0.9, 100_000.0, 1)).run();
        assert!((r1.avg_server_count - r2.avg_server_count).abs() / r2.avg_server_count < 0.05);
        assert!((r1.avg_running_count - r2.avg_running_count).abs() / r2.avg_running_count < 0.05);
        // Cold start probabilities are both sub-1%.
        assert!(r1.cold_start_prob < 0.01 && r2.cold_start_prob < 0.01);
    }

    #[test]
    fn higher_concurrency_needs_fewer_instances() {
        // Paper Fig. 1: c=3 absorbs the same traffic with fewer instances.
        let r1 = ParServerlessSimulator::new(cfg(3.0, 100_000.0, 2), 1).run();
        let r3 = ParServerlessSimulator::new(cfg(3.0, 100_000.0, 2), 3).run();
        assert!(
            r3.avg_server_count < r1.avg_server_count,
            "c=3 {} vs c=1 {}",
            r3.avg_server_count,
            r1.avg_server_count
        );
        assert!(r3.cold_start_prob <= r1.cold_start_prob + 0.01);
    }

    #[test]
    fn in_flight_never_exceeds_capacity() {
        let mut sim = ParServerlessSimulator::new(cfg(5.0, 5_000.0, 3), 4);
        let _ = sim.run();
        for inst in sim.instances() {
            assert!(inst.in_flight <= 4);
        }
    }

    #[test]
    fn rejection_when_capacity_exhausted() {
        let mut c = cfg(50.0, 5_000.0, 4);
        c.max_concurrency = 3;
        let r = ParServerlessSimulator::new(c, 2).run();
        // Offered load 50*2 ~ 100 >> 6 slots.
        assert!(r.rejection_prob > 0.5);
    }

    #[test]
    fn busy_counter_matches_full_scan() {
        // The incrementally-maintained busy-instance counter must agree
        // with a from-scratch recount of every instance ever created (the
        // seed's per-event O(n) scan, now a test-only oracle).
        for seed in [5u64, 6, 7] {
            let mut sim = ParServerlessSimulator::new(cfg(8.0, 10_000.0, seed), 3);
            let _ = sim.run();
            let scan = sim.instances().iter().filter(|i| i.is_busy()).count();
            let (_, busy, _) = sim.live_counts();
            assert_eq!(busy, scan, "seed {seed}");
        }
    }

    #[test]
    fn enum_and_custom_dispatch_bit_identical() {
        // Regression vs the seed behavior: swapping the monomorphic enum
        // for the trait-object escape hatch (the seed's dispatch mechanism)
        // changes nothing on a fixed seed — counters, averages, and the
        // new percentile estimators all match bit-for-bit.
        let base = cfg(5.0, 50_000.0, 9);
        let mut custom = base.clone();
        custom.arrival = Process::custom(ExpProcess::with_rate(5.0));
        custom.warm_service = Process::custom(ExpProcess::with_mean(1.991));
        custom.cold_service = Process::custom(ExpProcess::with_mean(2.244));
        let a = ParServerlessSimulator::new(base, 2).run();
        let b = ParServerlessSimulator::new(custom, 2).run();
        assert_eq!(a.total_requests, b.total_requests);
        assert_eq!(a.cold_requests, b.cold_requests);
        assert_eq!(a.warm_requests, b.warm_requests);
        assert_eq!(a.instances_expired, b.instances_expired);
        assert_eq!(a.avg_server_count.to_bits(), b.avg_server_count.to_bits());
        assert_eq!(
            a.billed_instance_seconds.to_bits(),
            b.billed_instance_seconds.to_bits()
        );
        assert_eq!(a.response_p95.to_bits(), b.response_p95.to_bits());
    }

    #[test]
    fn c1_is_bit_identical_to_scale_per_request_simulator() {
        // With c=1 and a deterministic expiration threshold the two
        // engines are the *same* core configuration drawing the same RNG
        // stream — the unification makes the historical percentile-level
        // agreement an exact bitwise identity.
        let c = cfg(0.9, 100_000.0, 11);
        let par = ParServerlessSimulator::new(c.clone(), 1).run();
        let spr = ServerlessSimulator::new(c).run();
        assert_eq!(par.total_requests, spr.total_requests);
        assert_eq!(par.cold_requests, spr.cold_requests);
        assert_eq!(par.warm_requests, spr.warm_requests);
        assert_eq!(par.instances_expired, spr.instances_expired);
        assert_eq!(par.avg_server_count.to_bits(), spr.avg_server_count.to_bits());
        assert_eq!(par.avg_running_count.to_bits(), spr.avg_running_count.to_bits());
        assert_eq!(par.avg_idle_count.to_bits(), spr.avg_idle_count.to_bits());
        assert_eq!(par.response_p50.to_bits(), spr.response_p50.to_bits());
        assert_eq!(par.response_p95.to_bits(), spr.response_p95.to_bits());
        assert_eq!(par.response_p99.to_bits(), spr.response_p99.to_bits());
        assert_eq!(
            par.billed_instance_seconds.to_bits(),
            spr.billed_instance_seconds.to_bits()
        );
        // Percentiles are ordered and bracket the mean sanely.
        assert!(par.response_p50.is_finite() && par.response_p50 > 0.0);
        assert!(par.response_p50 <= par.response_p95);
        assert!(par.response_p95 <= par.response_p99);
    }
}
