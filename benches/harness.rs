//! Shared micro-bench harness for the paper-figure benches.
//!
//! No external bench crates are available in this environment, so each
//! bench target is a plain binary (`harness = false`) including this module
//! via `#[path = "harness.rs"]`. It provides wall-clock measurement with
//! warm-up, repetition statistics, and uniform reporting, so `cargo bench`
//! output is comparable across targets.

use std::time::Instant;

/// Result of one measured benchmark.
#[derive(Debug, Clone)]
pub struct BenchResult {
    pub name: String,
    pub iters: usize,
    pub mean_s: f64,
    pub std_s: f64,
    pub min_s: f64,
    pub max_s: f64,
}

impl BenchResult {
    pub fn report(&self) {
        println!(
            "bench {:<44} {:>10.4} s/iter (±{:.4}, min {:.4}, max {:.4}, n={})",
            self.name, self.mean_s, self.std_s, self.min_s, self.max_s, self.iters
        );
    }
}

/// Run `f` `iters` times (after one warm-up call) and report timing stats.
/// Returns the last iteration's output for further inspection.
pub fn bench<T>(name: &str, iters: usize, mut f: impl FnMut() -> T) -> (BenchResult, T) {
    assert!(iters >= 1);
    let _warmup = f();
    let mut times = Vec::with_capacity(iters);
    let mut last = None;
    for _ in 0..iters {
        let t0 = Instant::now();
        let out = f();
        times.push(t0.elapsed().as_secs_f64());
        last = Some(out);
    }
    let n = times.len() as f64;
    let mean = times.iter().sum::<f64>() / n;
    let var = if times.len() > 1 {
        times.iter().map(|t| (t - mean) * (t - mean)).sum::<f64>() / (n - 1.0)
    } else {
        0.0
    };
    let result = BenchResult {
        name: name.to_string(),
        iters,
        mean_s: mean,
        std_s: var.sqrt(),
        min_s: times.iter().cloned().fold(f64::INFINITY, f64::min),
        max_s: times.iter().cloned().fold(f64::NEG_INFINITY, f64::max),
    };
    result.report();
    (result, last.unwrap())
}

/// Quick-mode switch: `SIMFAAS_BENCH_QUICK=1` shrinks horizons so the whole
/// suite stays in CI budgets; full mode reproduces the paper-scale runs.
pub fn quick() -> bool {
    std::env::var("SIMFAAS_BENCH_QUICK").map(|v| v == "1").unwrap_or(false)
}

/// Standard header so bench outputs are self-describing in bench_output.txt.
pub fn header(id: &str, what: &str, paper: &str) {
    println!("==============================================================");
    println!("{id}: {what}");
    println!("paper reference: {paper}");
    println!("==============================================================");
}
