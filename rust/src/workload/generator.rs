//! Open-loop workload generators.
//!
//! The paper's experiments drive AWS Lambda with an open-loop Poisson client
//! (their in-house `pacswg` library). This module is the equivalent
//! substrate: it materializes arrival timestamp vectors for the emulator and
//! for trace-driven simulation — Poisson, deterministic (cron), batch
//! (paper §4.2 calls out batch arrivals as beyond Markovian models), MMPP
//! bursty traffic, and non-homogeneous Poisson with an arbitrary rate
//! profile (used by the Azure-style diurnal traces).

use crate::sim::process::SimProcess;
use crate::sim::rng::Rng;

/// A materialized open-loop workload: sorted arrival times in seconds.
#[derive(Debug, Clone, Default)]
pub struct Workload {
    pub arrivals: Vec<f64>,
}

impl Workload {
    pub fn len(&self) -> usize {
        self.arrivals.len()
    }

    pub fn is_empty(&self) -> bool {
        self.arrivals.is_empty()
    }

    /// Observed average rate over the horizon.
    pub fn rate_over(&self, horizon: f64) -> f64 {
        if horizon <= 0.0 {
            0.0
        } else {
            self.arrivals.len() as f64 / horizon
        }
    }

    /// Merge two workloads (e.g. two functions sharing a client).
    ///
    /// Both arrival vectors are already sorted (every generator emits
    /// non-decreasing timestamps), so this is a linear two-way merge —
    /// O(n+m) instead of the previous extend-then-sort's O((n+m) log(n+m)).
    pub fn merge(self, other: &Workload) -> Workload {
        let a = self.arrivals;
        let b = &other.arrivals;
        debug_assert!(a.windows(2).all(|w| w[0] <= w[1]), "left workload unsorted");
        debug_assert!(b.windows(2).all(|w| w[0] <= w[1]), "right workload unsorted");
        let mut out = Vec::with_capacity(a.len() + b.len());
        let (mut i, mut j) = (0, 0);
        while i < a.len() && j < b.len() {
            if a[i] <= b[j] {
                out.push(a[i]);
                i += 1;
            } else {
                out.push(b[j]);
                j += 1;
            }
        }
        out.extend_from_slice(&a[i..]);
        out.extend_from_slice(&b[j..]);
        Workload { arrivals: out }
    }

    /// Inter-arrival gaps (empirical process input).
    pub fn gaps(&self) -> Vec<f64> {
        self.arrivals
            .windows(2)
            .map(|w| w[1] - w[0])
            .collect()
    }
}

/// Homogeneous Poisson arrivals at `rate` over `[0, horizon)`.
pub fn poisson(rate: f64, horizon: f64, rng: &mut Rng) -> Workload {
    assert!(rate > 0.0 && horizon > 0.0);
    let mut t = 0.0;
    let mut arrivals = Vec::with_capacity((rate * horizon * 1.1) as usize + 16);
    loop {
        t += rng.exponential(rate);
        if t >= horizon {
            break;
        }
        arrivals.push(t);
    }
    Workload { arrivals }
}

/// Deterministic arrivals every `interval` seconds starting at `offset`
/// (cron-style triggers).
pub fn deterministic(interval: f64, offset: f64, horizon: f64) -> Workload {
    assert!(interval > 0.0);
    let mut arrivals = Vec::new();
    let mut t = offset;
    while t < horizon {
        arrivals.push(t);
        t += interval;
    }
    Workload { arrivals }
}

/// Batch arrivals: batch epochs are Poisson(`batch_rate`); each epoch brings
/// `1 + Poisson(mean_batch_size - 1)` simultaneous requests.
pub fn batch(batch_rate: f64, mean_batch_size: f64, horizon: f64, rng: &mut Rng) -> Workload {
    assert!(batch_rate > 0.0 && mean_batch_size >= 1.0);
    let mut arrivals = Vec::new();
    let mut t = 0.0;
    loop {
        t += rng.exponential(batch_rate);
        if t >= horizon {
            break;
        }
        let size = 1 + rng.poisson(mean_batch_size - 1.0);
        for _ in 0..size {
            arrivals.push(t);
        }
    }
    Workload { arrivals }
}

/// Arrivals driven by any [`SimProcess`] used as the inter-arrival process.
pub fn from_process(process: &dyn SimProcess, horizon: f64, rng: &mut Rng) -> Workload {
    let mut arrivals = Vec::new();
    let mut t = 0.0;
    loop {
        t += process.sample(rng);
        if t >= horizon {
            break;
        }
        arrivals.push(t);
    }
    Workload { arrivals }
}

/// Non-homogeneous Poisson via thinning (Lewis & Shedler): `rate(t)` must be
/// bounded by `rate_max` on `[0, horizon)`.
pub fn nonhomogeneous<F: Fn(f64) -> f64>(
    rate: F,
    rate_max: f64,
    horizon: f64,
    rng: &mut Rng,
) -> Workload {
    assert!(rate_max > 0.0);
    let mut arrivals = Vec::new();
    let mut t = 0.0;
    loop {
        t += rng.exponential(rate_max);
        if t >= horizon {
            break;
        }
        let r = rate(t);
        debug_assert!(r <= rate_max * (1.0 + 1e-9), "rate(t) exceeds rate_max");
        if rng.uniform() * rate_max < r {
            arrivals.push(t);
        }
    }
    Workload { arrivals }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn poisson_rate_matches() {
        let mut rng = Rng::new(1);
        let w = poisson(2.0, 100_000.0, &mut rng);
        let rate = w.rate_over(100_000.0);
        assert!((rate - 2.0).abs() < 0.05, "rate={rate}");
        assert!(w.arrivals.windows(2).all(|x| x[1] >= x[0]));
    }

    #[test]
    fn deterministic_grid() {
        let w = deterministic(60.0, 0.0, 3600.0);
        assert_eq!(w.len(), 60);
        assert_eq!(w.arrivals[1] - w.arrivals[0], 60.0);
    }

    #[test]
    fn batch_brings_simultaneous_arrivals() {
        let mut rng = Rng::new(2);
        let w = batch(0.1, 5.0, 100_000.0, &mut rng);
        // Average rate = batch_rate * mean_batch_size = 0.5
        let rate = w.rate_over(100_000.0);
        assert!((rate - 0.5).abs() < 0.05, "rate={rate}");
        // Simultaneity: many zero gaps.
        let zero_gaps = w.gaps().iter().filter(|&&g| g == 0.0).count();
        assert!(zero_gaps > w.len() / 2);
    }

    #[test]
    fn nonhomogeneous_diurnal_shape() {
        let mut rng = Rng::new(3);
        let day = 86_400.0;
        // Sinusoidal profile peaking mid-day.
        let rate = |t: f64| 1.0 + (2.0 * std::f64::consts::PI * t / day).sin().max(-1.0);
        let w = nonhomogeneous(rate, 2.0, day, &mut rng);
        // First half (rising sine, rate>1) denser than second half.
        let mid = day / 2.0;
        let first = w.arrivals.iter().filter(|&&t| t < mid).count();
        let second = w.len() - first;
        assert!(first > second, "first={first} second={second}");
    }

    #[test]
    fn merge_sorts() {
        let a = Workload { arrivals: vec![1.0, 3.0] };
        let b = Workload { arrivals: vec![2.0, 4.0] };
        let m = a.merge(&b);
        assert_eq!(m.arrivals, vec![1.0, 2.0, 3.0, 4.0]);
    }

    #[test]
    fn merge_equals_sorted_union() {
        let mut rng = Rng::new(40);
        let a = poisson(1.5, 10_000.0, &mut rng);
        let b = batch(0.2, 4.0, 10_000.0, &mut rng); // has duplicate times
        let mut expected: Vec<f64> =
            a.arrivals.iter().chain(&b.arrivals).copied().collect();
        expected.sort_by(|x, y| x.partial_cmp(y).unwrap());
        let merged = a.clone().merge(&b);
        assert_eq!(merged.arrivals, expected);
        assert_eq!(merged.len(), a.len() + b.len());
        // Merging with an empty workload is the identity.
        let empty = Workload::default();
        assert_eq!(a.clone().merge(&empty).arrivals, a.arrivals);
        assert_eq!(empty.merge(&a).arrivals, a.arrivals);
    }

    #[test]
    fn from_process_respects_horizon() {
        use crate::sim::process::ConstProcess;
        let mut rng = Rng::new(4);
        let w = from_process(&ConstProcess::new(10.0), 95.0, &mut rng);
        assert_eq!(w.len(), 9);
        assert!(w.arrivals.iter().all(|&t| t < 95.0));
    }
}
