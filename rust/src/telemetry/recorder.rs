//! The sink trait, the default in-memory recorder, and the [`Observer`]
//! handle an engine core carries while telemetry is enabled.

use super::span::{SpanRecord, StateSample};

/// Destination for telemetry records. The engines call this through an
/// [`Observer`]; the default sink is the in-memory [`TelemetryRecorder`],
/// but embedders can supply their own (streaming, filtering, counting)
/// via [`Observer::with_sink`].
///
/// Implementations must not depend on wall-clock time or randomness:
/// telemetry capture sits inside the deterministic event loop and the
/// recorded stream must be a pure function of the run.
pub trait TelemetrySink {
    /// Record one request-dispatch span.
    fn record_span(&mut self, span: SpanRecord);
    /// Record one periodic internal-state sample.
    fn record_sample(&mut self, sample: StateSample);
}

/// The default sink: buffers every record in memory, in emission order
/// (spans by dispatch time, samples by sample time — both nondecreasing
/// within one engine).
#[derive(Debug, Clone, Default, PartialEq)]
pub struct TelemetryRecorder {
    /// Captured spans in dispatch order.
    pub spans: Vec<SpanRecord>,
    /// Captured samples in time order.
    pub samples: Vec<StateSample>,
}

impl TelemetryRecorder {
    /// An empty recorder.
    pub fn new() -> TelemetryRecorder {
        TelemetryRecorder::default()
    }
}

impl TelemetrySink for TelemetryRecorder {
    fn record_span(&mut self, span: SpanRecord) {
        self.spans.push(span);
    }

    fn record_sample(&mut self, sample: StateSample) {
        self.samples.push(sample);
    }
}

enum SinkKind {
    Recorder(TelemetryRecorder),
    Custom(Box<dyn TelemetrySink + Send>),
}

/// The telemetry hook an [`crate::sim::EngineCore`] owns while recording:
/// a sink plus the sampling cursor. Attaching one never changes simulation
/// results — capture draws no RNG and schedules no events — and a core
/// without one pays a single `Option` branch per dispatch (the
/// zero-overhead contract, same as the fault lane).
pub struct Observer {
    function: u32,
    sample_interval: f64,
    /// Next sample instant; lazily initialized by the core to the start of
    /// the measured window (the engine's `skip_initial` boundary).
    next_sample_at: Option<f64>,
    sink: SinkKind,
}

impl Observer {
    /// An observer buffering into a fresh [`TelemetryRecorder`].
    /// `sample_interval <= 0` records spans only.
    pub fn recording(function: u32, sample_interval: f64) -> Observer {
        Observer {
            function,
            sample_interval,
            next_sample_at: None,
            sink: SinkKind::Recorder(TelemetryRecorder::new()),
        }
    }

    /// An observer forwarding to a caller-supplied sink.
    pub fn with_sink(
        function: u32,
        sample_interval: f64,
        sink: Box<dyn TelemetrySink + Send>,
    ) -> Observer {
        Observer { function, sample_interval, next_sample_at: None, sink: SinkKind::Custom(sink) }
    }

    /// Fleet function index stamped on every record (0 outside fleets).
    pub fn function(&self) -> u32 {
        self.function
    }

    /// Sampling interval in simulation seconds (`<= 0` = spans only).
    pub fn sample_interval(&self) -> f64 {
        self.sample_interval
    }

    /// Current sampling cursor (`None` until the first tick).
    pub fn next_sample_at(&self) -> Option<f64> {
        self.next_sample_at
    }

    /// Advance the sampling cursor.
    pub fn set_next_sample_at(&mut self, t: f64) {
        self.next_sample_at = Some(t);
    }

    /// Forward one span to the sink.
    pub fn record_span(&mut self, span: SpanRecord) {
        match &mut self.sink {
            SinkKind::Recorder(r) => r.record_span(span),
            SinkKind::Custom(s) => s.record_span(span),
        }
    }

    /// Forward one sample to the sink.
    pub fn record_sample(&mut self, sample: StateSample) {
        match &mut self.sink {
            SinkKind::Recorder(r) => r.record_sample(sample),
            SinkKind::Custom(s) => s.record_sample(sample),
        }
    }

    /// Recover the buffered records (`None` for custom sinks, which own
    /// their output).
    pub fn into_recorder(self) -> Option<TelemetryRecorder> {
        match self.sink {
            SinkKind::Recorder(r) => Some(r),
            SinkKind::Custom(_) => None,
        }
    }
}
