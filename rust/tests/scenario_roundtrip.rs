//! Scenario-layer integration tests: bundled spec files parse (golden
//! files), builder → JSON → parse → run is bit-identical to builder → run,
//! and malformed files fail with errors that name the problem.

use simfaas::scenario::{
    run_scenario, run_scenario_to_string, ExperimentSpec, FleetScenario, KeepAliveSpec,
    OutputFormat, ProcessSpec, ScenarioReport, ScenarioSpec,
};
use std::path::PathBuf;

fn scenarios_dir() -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("../examples/scenarios")
}

#[test]
fn every_bundled_scenario_parses_and_validates() {
    let mut seen = 0;
    for entry in std::fs::read_dir(scenarios_dir()).expect("examples/scenarios exists") {
        let path = entry.unwrap().path();
        if path.extension().and_then(|e| e.to_str()) != Some("json") {
            continue;
        }
        let text = std::fs::read_to_string(&path).unwrap();
        let spec = ScenarioSpec::from_json_str(&text)
            .unwrap_or_else(|e| panic!("{path:?} failed to parse: {e:#}"));
        spec.validate().unwrap_or_else(|e| panic!("{path:?} failed to validate: {e:#}"));
        assert!(!spec.name.is_empty(), "{path:?} has an empty name");
        // Canonical re-serialization still parses to the same spec.
        let back = ScenarioSpec::from_json_str(&spec.to_json_string()).unwrap();
        assert_eq!(back, spec, "{path:?} is not canonical-stable");
        seen += 1;
    }
    assert!(seen >= 8, "expected the bundled scenario set, found {seen}");
}

#[test]
fn golden_table1_scenario_has_expected_fields() {
    let text = std::fs::read_to_string(scenarios_dir().join("table1_steady.json")).unwrap();
    let spec = ScenarioSpec::from_json_str(&text).unwrap();
    assert_eq!(spec.name, "table1-steady");
    assert_eq!(spec.experiment, ExperimentSpec::Steady);
    assert_eq!(spec.workload.arrival, ProcessSpec::ExpRate(0.9));
    assert_eq!(spec.platform.warm_service, ProcessSpec::ExpMean(1.991));
    assert_eq!(spec.platform.cold_service, ProcessSpec::ExpMean(2.244));
    assert_eq!(spec.platform.expiration_threshold, 600.0);
    assert_eq!(spec.platform.max_concurrency, 1000);
    assert_eq!(spec.run.horizon, 200_000.0);
    assert_eq!(spec.run.skip_initial, 100.0);
    assert_eq!(spec.run.seed, 0x5EED);
    assert_eq!(spec.output.format, OutputFormat::Table);
    assert!(spec.cost.is_none());
}

#[test]
fn golden_fleet_comparison_scenario_has_expected_shape() {
    let text =
        std::fs::read_to_string(scenarios_dir().join("fleet_policy_comparison.json")).unwrap();
    let spec = ScenarioSpec::from_json_str(&text).unwrap();
    match &spec.experiment {
        ExperimentSpec::Fleet(f) => {
            assert_eq!(f.functions, 10);
            assert_eq!(f.compare_thresholds, vec![60.0, 600.0]);
            assert_eq!(f.compare_extra.len(), 1);
            assert!(matches!(f.compare_extra[0], KeepAliveSpec::HybridHistogram { .. }));
        }
        other => panic!("expected fleet experiment, got {other:?}"),
    }
    assert_eq!(spec.run.seed, 0xCAFE);
}

/// The acceptance contract: builder → JSON → parse → run must be
/// bit-identical to builder → run, for a spec exercising every axis.
#[test]
fn json_roundtrip_execution_is_bit_identical() {
    let specs = vec![
        ScenarioSpec::new("steady-rt")
            .with_arrival(ProcessSpec::Mmpp { rates: [1.8, 0.2], switch: [0.03, 0.04] })
            .with_batch_size(ProcessSpec::Constant(2.0))
            .with_services(
                ProcessSpec::LogNormal { mean: 1.4, cv: 0.5 },
                ProcessSpec::Weibull { shape: 2.0, scale: 2.5 },
            )
            .with_expiration_process(ProcessSpec::Gaussian { mean: 500.0, std: 40.0 })
            .with_horizon(5_000.0)
            .with_seed(11),
        ScenarioSpec::new("ensemble-rt")
            .with_horizon(3_000.0)
            .with_seed(13)
            .with_experiment(ExperimentSpec::Ensemble {
                replications: 4,
                threads: 2,
                thresholds: vec![120.0, 900.0],
            }),
        ScenarioSpec::new("fleet-rt")
            .with_horizon(1_200.0)
            .with_skip_initial(0.0)
            .with_seed(17)
            .with_experiment(ExperimentSpec::Fleet(
                FleetScenario::new(6)
                    .with_policy(KeepAliveSpec::hybrid_histogram(1_800.0, 30.0))
                    .with_threads(2),
            )),
    ];
    for spec in specs {
        let reparsed = ScenarioSpec::from_json_str(&spec.to_json_string()).unwrap();
        assert_eq!(reparsed, spec, "{} changed across serialization", spec.name);
        // Rendered text must match exactly, and — since rendering rounds —
        // the underlying reports are also compared bit-for-bit below.
        let a = run_scenario_to_string(&spec).unwrap();
        let b = run_scenario_to_string(&reparsed).unwrap();
        assert_eq!(a, b, "{} render diverged after round trip", spec.name);
        let (ra, rb) = (run_scenario(&spec).unwrap(), run_scenario(&reparsed).unwrap());
        match (ra, rb) {
            (
                ScenarioReport::Steady { results: x, .. },
                ScenarioReport::Steady { results: y, .. },
            ) => {
                assert_eq!(x.total_requests, y.total_requests);
                assert_eq!(x.cold_start_prob.to_bits(), y.cold_start_prob.to_bits());
                assert_eq!(x.avg_server_count.to_bits(), y.avg_server_count.to_bits());
            }
            (
                ScenarioReport::EnsembleGrid { grid: x, .. },
                ScenarioReport::EnsembleGrid { grid: y, .. },
            ) => {
                for ((tha, ea), (thb, eb)) in x.iter().zip(&y) {
                    assert_eq!(tha, thb);
                    for (p, q) in ea.runs.iter().zip(&eb.runs) {
                        assert_eq!(p.total_requests, q.total_requests);
                        assert_eq!(
                            p.avg_server_count.to_bits(),
                            q.avg_server_count.to_bits()
                        );
                    }
                }
            }
            (
                ScenarioReport::Fleet { results: x, cost: cx, .. },
                ScenarioReport::Fleet { results: y, cost: cy, .. },
            ) => {
                assert_eq!(x.names, y.names);
                assert_eq!(x.aggregate.total_requests, y.aggregate.total_requests);
                assert_eq!(
                    x.aggregate.avg_server_count.to_bits(),
                    y.aggregate.avg_server_count.to_bits()
                );
                assert_eq!(
                    cx.total.developer_total().to_bits(),
                    cy.total.developer_total().to_bits()
                );
            }
            _ => panic!("report kinds diverged"),
        }
    }
}

#[test]
fn malformed_scenarios_fail_with_named_errors() {
    for (text, needle) in [
        // Not JSON at all.
        ("{ not json", "not valid JSON"),
        // Wrong top-level shape.
        ("[1,2,3]", "scenario must be a JSON object"),
        // Missing required fields.
        (r#"{"name":"x"}"#, "experiment"),
        // Unknown experiment type lists the accepted set.
        (
            r#"{"name":"x","experiment":{"type":"autoscale"}}"#,
            "steady|temporal|ensemble|sweep|compare|fleet",
        ),
        // Typo'd key (the scenario analogue of an unknown flag).
        (
            r#"{"name":"x","experiment":{"type":"steady"},"platform":{"warm_servce":{"type":"exp","rate":1}}}"#,
            "unknown key",
        ),
        // Bad process parameterization.
        (
            r#"{"name":"x","experiment":{"type":"steady"},"workload":{"arrival":{"type":"exp"}}}"#,
            "exactly one",
        ),
        // Type error with the field path.
        (
            r#"{"name":"x","experiment":{"type":"ensemble","replications":"ten"}}"#,
            "experiment.replications",
        ),
        // Bad provider name lists the options.
        (
            r#"{"name":"x","experiment":{"type":"steady"},"cost":{"provider":"oraclecloud"}}"#,
            "aws|gcf|google|azure|ibm",
        ),
    ] {
        let err = format!("{:#}", ScenarioSpec::from_json_str(text).unwrap_err());
        assert!(err.contains(needle), "input {text:?}: error {err:?} lacks {needle:?}");
    }

    // Semantically invalid (well-formed JSON) fails at run time with the
    // field named.
    let spec = ScenarioSpec::from_json_str(
        r#"{"name":"x","experiment":{"type":"temporal","replications":0}}"#,
    )
    .unwrap();
    let err = run_scenario(&spec).unwrap_err().to_string();
    assert!(err.contains("temporal.replications"), "{err}");
}

/// The cluster axis round-trips through JSON, executes identically after
/// the round trip, and its validation rejections name the fields.
#[test]
fn cluster_axis_roundtrips_and_validates() {
    use simfaas::{ClusterConfig, SchedulerSpec};

    let spec = ScenarioSpec::new("cluster-rt")
        .with_horizon(1_200.0)
        .with_skip_initial(0.0)
        .with_seed(23)
        .with_experiment(ExperimentSpec::Fleet(FleetScenario::new(6).with_cluster(
            ClusterConfig::new(2, 512.0, 4.0).with_scheduler(SchedulerSpec::PackingAware),
        )));
    let reparsed = ScenarioSpec::from_json_str(&spec.to_json_string()).unwrap();
    assert_eq!(reparsed, spec);
    let a = run_scenario_to_string(&spec).unwrap();
    let b = run_scenario_to_string(&reparsed).unwrap();
    assert_eq!(a, b);
    assert!(a.contains("scheduler packing"), "{a}");

    // cluster + fleet_cap is rejected with both fields named.
    let both = ScenarioSpec::new("both").with_experiment(ExperimentSpec::Fleet(
        FleetScenario::new(2)
            .with_fleet_cap(8)
            .with_cluster(ClusterConfig::new(1, 512.0, 4.0)),
    ));
    let err = both.validate().unwrap_err().to_string();
    assert!(err.contains("fleet.cluster") && err.contains("fleet.fleet_cap"), "{err}");

    // A zero-memory host is rejected before any simulation runs.
    let zero = ScenarioSpec::new("zero").with_experiment(ExperimentSpec::Fleet(
        FleetScenario::new(2).with_cluster(ClusterConfig::new(1, 0.0, 4.0)),
    ));
    let err = zero.validate().unwrap_err().to_string();
    assert!(err.contains("fleet.cluster") && err.contains("zero-memory"), "{err}");
}
