//! Expiration-threshold probing against the emulator (paper §5.2): drives
//! the platform with single requests at increasing gaps, observing cold
//! starts — the same experiment the paper ran against AWS Lambda, through
//! the same `trace::ident::ColdStartProbe` interface.

use super::platform::{EmulatorConfig, Platform};
use crate::trace::ident::ColdStartProbe;
use crate::trace::Outcome;
use crate::workload::Workload;

/// Stateless probe: each call runs a tiny two-request emulation (prime +
/// probe after the gap) and reports whether the second request was cold.
pub struct EmulatorProbe {
    cfg: EmulatorConfig,
}

impl EmulatorProbe {
    pub fn new(cfg: EmulatorConfig) -> Self {
        EmulatorProbe { cfg }
    }
}

impl ColdStartProbe for EmulatorProbe {
    fn probe(&mut self, gap: f64) -> bool {
        if gap <= 0.0 {
            // Prime call: first request on a fresh platform is always cold.
            return true;
        }
        let platform = Platform::new(self.cfg.clone(), None);
        // Request 1 primes an instance; request 2 arrives `gap` later
        // (measured from request 1's *completion*; add a service-time pad).
        let pad = 3.0; // generous bound on service completion
        let w = Workload { arrivals: vec![0.5, 0.5 + pad + gap] };
        let res = platform.run(&w).expect("probe emulation failed");
        let second = res
            .records
            .iter()
            .find(|r| r.arrived_at > 0.5 + pad / 2.0)
            .expect("second probe request missing");
        second.outcome == Outcome::Cold
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::process::ConstProcess;
    use crate::trace::ident::probe_expiration_threshold;
    use std::sync::Arc;

    #[test]
    fn probe_brackets_emulator_threshold() {
        let _guard = crate::emulator::emu_test_guard();
        let mut cfg = EmulatorConfig::lambda_like(5000.0);
        cfg.synthetic_service = Some(Arc::new(ConstProcess::new(1.0)));
        cfg.provisioning_delay = 0.2;
        cfg.expiration_threshold = 60.0;
        cfg.tick = 1.0;
        let mut probe = EmulatorProbe::new(cfg);
        let (lo, hi) = probe_expiration_threshold(&mut probe, 20.0, 20.0, 160.0);
        assert!(lo < 60.0 + 20.0 && hi >= 60.0 - 1.5, "bracket=({lo},{hi})");
        assert!(hi - lo <= 20.0 + 1e-9);
    }
}
